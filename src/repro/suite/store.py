"""Content-addressed run store: JSONL index + per-run npz payloads.

Layout (default root ``results/store/``, gitignored)::

    results/store/
      index.jsonl          # one RunRecord per line, append-only
      runs/<run_key>.npz   # the result payload, one file per run

The index is the queryable surface — every line carries the run key, the
scenario content hash, engine id, schema version, git sha, creation time,
wall time, and a small summary-metrics dict — so listing and trend analysis
never open a payload.  Payloads are plain ``npz`` archives (structure-of-
arrays outcome grids for :class:`~repro.engine.base.EngineResult`, per-cell
attempt-record columns for fleet grids, SLO/price grids for
:class:`~repro.serving.ServingResult`) with one JSON header entry; floats
ride either in float64 arrays or through JSON's exact shortest-round-trip
repr, so a store round trip is bit-for-bit.

Crash safety: the payload is written to a temp file and renamed, and the
index line is appended (and flushed) only afterwards — an interrupted run
leaves either a complete entry or no entry, never a torn one.  Re-appending
the same key later simply supersedes the older line (last wins on load);
:meth:`RunStore.gc` compacts superseded lines away and deletes payload
files nothing references (``repro-suite gc``).

Integrity: every payload's sha256 is computed over the exact bytes the
record describes and stored in the index line, so a torn write, bit rot, or
a foreign file under ``runs/`` is *detected* rather than surfacing as a raw
``zipfile.BadZipFile`` three layers up: :meth:`RunStore.load` verifies the
checksum (and wraps every decode failure) into a typed
:class:`StoreCorruptionError` carrying the run key and payload path, and
:meth:`RunStore.verify` sweeps the whole store — with ``repair=True``
quarantining corrupt entries under ``quarantine/`` and dropping their index
lines so the next ``repro-suite run`` simply re-simulates them
(``repro-suite verify [--repair]``).  The fault-injection sites
``store.payload_write`` (raise | torn) and ``store.index_append`` (raise)
from :mod:`repro.faults` live on this module's write path.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import math
import os
import pathlib
import subprocess
import time
import zipfile
from typing import Any, Mapping

import numpy as np

from repro import faults
from repro.core.billing import Termination
from repro.core.provision import SLA
from repro.core.schemes import Scheme
from repro.core.simulator import SimResult  # noqa: F401  (documented payload scope)
from repro.engine.base import EngineResult, PhaseTimings, SchemePhases
from repro.engine.fleetgrid import FleetGridResult
from repro.engine.scenario import FleetScenario, MarketCell, Scenario
from repro.fleet.controller import AttemptRecord, FleetResult, JobOutcome
from repro.fleet.sweep import SweepCell
from repro.fleet.workload import Job
from repro.obs import telemetry as obs
from repro.serving import ServingResult, ServingScenario
from repro.suite.hashing import SCHEMA_VERSION, run_key, scenario_hash

__all__ = [
    "GcStats",
    "RunRecord",
    "RunStore",
    "StoreCorruptionError",
    "VerifyStats",
    "DEFAULT_ROOT",
]

DEFAULT_ROOT = "results/store"

#: Header keys that legitimately differ between two runs of the same cell
#: (wall-clock measurements); payload parity ignores them.
_VOLATILE_HEADER_KEYS = ("wall_s", "timings")


class StoreCorruptionError(RuntimeError):
    """A stored payload failed its checksum or could not be decoded.

    Carries the run key and payload path so callers (and the
    ``repro-suite verify`` workflow) can quarantine the exact entry instead
    of crashing on a raw ``zipfile.BadZipFile``/``KeyError``.
    """

    def __init__(self, run_key: str, payload: "pathlib.Path | str", reason: str):
        self.run_key = run_key
        self.payload = str(payload)
        self.reason = reason
        super().__init__(f"corrupt run {run_key} ({self.payload}): {reason}")


def _git_sha() -> str | None:
    """Current commit sha, or None outside a usable git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True, timeout=10
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    return out.stdout.strip() if out.returncode == 0 else None


@dataclasses.dataclass(frozen=True)
class RunRecord:
    """One index line: everything about a run except its bulk payload."""

    run_key: str
    scenario_hash: str
    engine: str
    schema_version: int
    kind: str  # "scenario" | "fleet" | "serving"
    created_at: float  # unix seconds
    sha: str | None  # git commit the run was produced at
    payload: str  # path relative to the store root
    wall_s: float
    n_cells: int
    metrics: dict[str, float]
    suite: str | None = None
    cell: str | None = None
    sha256: str | None = None  # checksum of the payload bytes (None: pre-checksum record)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RunRecord":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass(frozen=True)
class GcStats:
    """What :meth:`RunStore.gc` reclaimed (or would reclaim, on a dry run)."""

    index_lines_before: int
    index_lines_after: int
    index_bytes_reclaimed: int
    payloads_deleted: list[str]  # store-relative paths
    payload_bytes_reclaimed: int
    dry_run: bool

    @property
    def bytes_reclaimed(self) -> int:
        return self.index_bytes_reclaimed + self.payload_bytes_reclaimed

    def summary(self) -> str:
        verb = "would reclaim" if self.dry_run else "reclaimed"
        return (
            f"index: {self.index_lines_before} -> {self.index_lines_after} lines; "
            f"{len(self.payloads_deleted)} orphaned payloads; "
            f"{verb} {self.bytes_reclaimed} bytes"
        )


@dataclasses.dataclass(frozen=True)
class VerifyStats:
    """What :meth:`RunStore.verify` found (and, with ``repair``, moved)."""

    n_records: int
    n_ok: int
    n_unchecksummed: int  # pre-checksum index lines: decode-checked only when deep
    corrupt: list[tuple[str, str]]  # (run_key, reason)
    quarantined: list[str]  # store-relative paths moved under quarantine/
    repaired: bool
    deep: bool

    @property
    def ok(self) -> bool:
        return not self.corrupt

    def summary(self) -> str:
        mode = "deep" if self.deep else "checksum"
        head = (
            f"{self.n_records} records ({mode} verify): {self.n_ok} ok, "
            f"{len(self.corrupt)} corrupt"
        )
        if self.n_unchecksummed:
            head += f", {self.n_unchecksummed} without checksums"
        if self.repaired:
            head += f"; quarantined {len(self.quarantined)} payloads"
        return head


class RunStore:
    """A persistent, content-addressed database of simulation runs."""

    def __init__(self, root: str | pathlib.Path = DEFAULT_ROOT):
        self.root = pathlib.Path(root)
        self.index_path = self.root / "index.jsonl"
        self.runs_dir = self.root / "runs"
        self.quarantine_dir = self.root / "quarantine"
        self._records: dict[str, RunRecord] = {}
        self._sha: str | None | bool = False  # False = not yet resolved
        self.reload()

    # -- index --------------------------------------------------------------

    def reload(self) -> None:
        """Re-read the index from disk (last line wins per key)."""
        self._records = {}
        if not self.index_path.exists():
            return
        for line in self.index_path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rec = RunRecord.from_dict(json.loads(line))
            except (json.JSONDecodeError, TypeError):
                continue  # torn/foreign line: ignorable, the payload re-runs
            self._records[rec.run_key] = rec

    def records(self) -> list[RunRecord]:
        """All index entries, oldest first."""
        return sorted(self._records.values(), key=lambda r: r.created_at)

    def get(self, key: str) -> RunRecord | None:
        return self._records.get(key)

    def has(self, key: str) -> bool:
        """True when the key is indexed *and* its payload file exists."""
        rec = self._records.get(key)
        return rec is not None and (self.root / rec.payload).exists()

    def __contains__(self, key: str) -> bool:
        return self.has(key)

    def __len__(self) -> int:
        return len(self._records)

    def _resolve_sha(self, sha: str | None) -> str | None:
        if sha is not None:
            return sha
        if self._sha is False:
            self._sha = _git_sha()
        return self._sha

    def _flush(self, rec: RunRecord, payload: dict[str, np.ndarray]) -> RunRecord:
        """Write payload-then-index (the interrupt-safety order).

        The payload is serialized in memory first so the index line's sha256
        describes the *intended* bytes — a write torn between serialization
        and disk (crash, or the ``store.payload_write`` fault site) is then
        detectable by :meth:`load`/:meth:`verify` instead of silent.
        """
        self.runs_dir.mkdir(parents=True, exist_ok=True)
        buf = io.BytesIO()
        np.savez_compressed(buf, **payload)
        data = buf.getvalue()
        rec = dataclasses.replace(rec, sha256=hashlib.sha256(data).hexdigest())
        final = self.root / rec.payload
        tmp = final.with_suffix(".tmp.npz")
        action = faults.current().fire("store.payload_write", key=rec.run_key)
        if action is not None and action.kind == "raise":
            # crash mid-write: a stale tmp file is left behind (gc's problem),
            # the final payload and the index are untouched
            tmp.write_bytes(data[: len(data) // 2])
            raise faults.InjectedFault(action)
        if action is not None and action.kind == "torn":
            # torn write the OS never reported: the commit completes but the
            # payload on disk is truncated — only the checksum can tell
            tmp.write_bytes(data[: len(data) // 2])
        else:
            tmp.write_bytes(data)
        os.replace(tmp, final)
        faults.current().check("store.index_append", key=rec.run_key)
        with self.index_path.open("a") as f:
            f.write(json.dumps(rec.asdict()) + "\n")
            f.flush()
        self._records[rec.run_key] = rec
        return rec

    # -- maintenance --------------------------------------------------------

    def gc(self, *, dry_run: bool = False) -> "GcStats":
        """Compact the index and delete orphaned payloads.

        The append-only index accumulates one superseded line per re-run of
        a key, and a superseded payload (or a run whose index append was
        interrupted) leaves an ``npz`` nothing references.  ``gc`` rewrites
        the index with only the surviving record per key (oldest first, via
        tmp-file + ``os.replace`` so a crash leaves the old or the new index,
        never a torn one) and unlinks every file under ``runs/`` no surviving
        record points to — including stale ``.tmp.npz`` leftovers.

        ``dry_run=True`` reports what would be reclaimed without touching
        disk.  Returns :class:`GcStats`.
        """
        self.reload()
        lines_before = 0
        index_bytes_before = 0
        if self.index_path.exists():
            text = self.index_path.read_text()
            index_bytes_before = len(text.encode())
            lines_before = sum(1 for ln in text.splitlines() if ln.strip())
        recs = self.records()
        new_text = "".join(json.dumps(r.asdict()) + "\n" for r in recs)
        referenced = {(self.root / r.payload).resolve() for r in recs}
        orphans = []
        if self.runs_dir.is_dir():
            orphans = sorted(
                p for p in self.runs_dir.glob("*.npz") if p.resolve() not in referenced
            )
        payload_bytes = sum(p.stat().st_size for p in orphans)
        if not dry_run:
            if self.index_path.exists():
                tmp = self.index_path.with_suffix(".jsonl.tmp")
                tmp.write_text(new_text)
                os.replace(tmp, self.index_path)
            for p in orphans:
                p.unlink()
        return GcStats(
            index_lines_before=lines_before,
            index_lines_after=len(recs),
            index_bytes_reclaimed=index_bytes_before - len(new_text.encode()),
            payloads_deleted=[str(p.relative_to(self.root)) for p in orphans],
            payload_bytes_reclaimed=payload_bytes,
            dry_run=dry_run,
        )

    # -- put ----------------------------------------------------------------

    def put_engine_result(
        self,
        scenario: Scenario,
        result: EngineResult,
        *,
        engine: str | None = None,
        suite: str | None = None,
        cell: str | None = None,
        sha: str | None = None,
    ) -> RunRecord:
        """Persist one single-scenario run; returns its index record."""
        engine = engine or result.engine
        key = run_key(scenario, engine)
        rec = RunRecord(
            run_key=key,
            scenario_hash=scenario_hash(scenario),
            engine=engine,
            schema_version=SCHEMA_VERSION,
            kind="scenario",
            created_at=time.time(),
            sha=self._resolve_sha(sha),
            payload=f"runs/{key}.npz",
            wall_s=float(result.wall_s),
            n_cells=result.n_cells,
            metrics=_engine_metrics(result),
            suite=suite,
            cell=cell,
        )
        return self._flush(rec, _pack_engine_result(scenario, result))

    def put_fleet_result(
        self,
        scenario: FleetScenario,
        grid: FleetGridResult,
        *,
        suite: str | None = None,
        cell: str | None = None,
        sha: str | None = None,
    ) -> RunRecord:
        """Persist one fleet-grid run (engine id ``"fleet"``: the scalar
        controller is the only fleet backend)."""
        key = run_key(scenario, "fleet")
        rec = RunRecord(
            run_key=key,
            scenario_hash=scenario_hash(scenario),
            engine="fleet",
            schema_version=SCHEMA_VERSION,
            kind="fleet",
            created_at=time.time(),
            sha=self._resolve_sha(sha),
            payload=f"runs/{key}.npz",
            wall_s=float(grid.wall_s),
            n_cells=len(grid.cells),
            metrics=_fleet_metrics(grid),
            suite=suite,
            cell=cell,
        )
        return self._flush(rec, _pack_fleet_grid(scenario, grid))

    def put_serving_result(
        self,
        scenario: ServingScenario,
        result: ServingResult,
        *,
        engine: str | None = None,
        suite: str | None = None,
        cell: str | None = None,
        sha: str | None = None,
    ) -> RunRecord:
        """Persist one serving-grid run; returns its index record."""
        engine = engine or result.engine
        key = run_key(scenario, engine)
        rec = RunRecord(
            run_key=key,
            scenario_hash=scenario_hash(scenario),
            engine=engine,
            schema_version=SCHEMA_VERSION,
            kind="serving",
            created_at=time.time(),
            sha=self._resolve_sha(sha),
            payload=f"runs/{key}.npz",
            wall_s=float(result.wall_s),
            n_cells=result.n_cells,
            metrics=_serving_metrics(result),
            suite=suite,
            cell=cell,
        )
        return self._flush(rec, _pack_serving_result(scenario, result))

    # -- load ---------------------------------------------------------------

    def load(
        self,
        record_or_key: RunRecord | str,
        scenario: Scenario | FleetScenario | None = None,
    ) -> EngineResult | FleetGridResult:
        """Reconstruct a stored result.

        Pass the materialized ``scenario`` when you have it (the runner
        does) to get it attached to the result; without it the result's
        ``scenario`` is ``None`` and market cells carry no trace — the
        outcome arrays and metadata are complete either way.  Engine-result
        payloads store the SoA grid only: per-run ``sim_results`` lists (a
        reference-engine debugging aid) are not persisted.
        """
        rec = record_or_key if isinstance(record_or_key, RunRecord) else self._records[record_or_key]
        data = self._read_verified(rec)
        try:
            with np.load(io.BytesIO(data)) as z:
                if rec.kind == "fleet":
                    return _unpack_fleet_grid(z, scenario)
                if rec.kind == "serving":
                    return _unpack_serving_result(z)
                return _unpack_engine_result(z, scenario)
        except (zipfile.BadZipFile, KeyError, ValueError, EOFError, OSError,
                json.JSONDecodeError) as e:
            raise StoreCorruptionError(
                rec.run_key, self.root / rec.payload, f"undecodable payload: {e!r}"
            ) from e

    def _read_verified(self, rec: RunRecord) -> bytes:
        """The payload bytes, checksum-verified when the record carries one."""
        path = self.root / rec.payload
        try:
            data = path.read_bytes()
        except OSError as e:
            raise StoreCorruptionError(rec.run_key, path, f"unreadable payload: {e}") from e
        if rec.sha256 is not None:
            got = hashlib.sha256(data).hexdigest()
            if got != rec.sha256:
                raise StoreCorruptionError(
                    rec.run_key, path,
                    f"checksum mismatch: index has {rec.sha256[:12]}…, payload is {got[:12]}…",
                )
        return data

    # -- verify / repair -----------------------------------------------------

    def verify(self, *, repair: bool = False, deep: bool = False) -> VerifyStats:
        """Sweep every indexed record for corruption.

        The default pass checks payload existence and sha256 (fast: no
        decode); ``deep=True`` additionally decodes every payload through the
        full codec.  With ``repair=True`` each corrupt entry is *quarantined*
        instead of left to crash a future load: its payload (when present)
        moves to ``quarantine/<run_key>.npz`` and its index line is dropped
        (tmp-file + ``os.replace``, same crash-safety as :meth:`gc`), so the
        next suite pass treats the cell as missing and re-simulates it.
        Counts ``store.quarantined`` per quarantined entry.
        """
        self.reload()
        n_records = len(self._records)
        corrupt: list[tuple[str, str]] = []
        quarantined: list[str] = []
        n_unchecksummed = 0
        for rec in self.records():
            n_unchecksummed += rec.sha256 is None
            try:
                data = self._read_verified(rec)
                if deep:
                    with np.load(io.BytesIO(data)) as z:
                        if rec.kind == "fleet":
                            _unpack_fleet_grid(z, None)
                        elif rec.kind == "serving":
                            _unpack_serving_result(z)
                        else:
                            _unpack_engine_result(z, None)
            except StoreCorruptionError as e:
                corrupt.append((rec.run_key, e.reason))
            except (zipfile.BadZipFile, KeyError, ValueError, EOFError, OSError,
                    json.JSONDecodeError) as e:
                corrupt.append((rec.run_key, f"undecodable payload: {e!r}"))
        if repair and corrupt:
            tel = obs.current()
            bad_keys = {k for k, _ in corrupt}
            for key in sorted(bad_keys):
                rec = self._records[key]
                src = self.root / rec.payload
                if src.exists():
                    self.quarantine_dir.mkdir(parents=True, exist_ok=True)
                    dst = self.quarantine_dir / f"{rec.run_key}.npz"
                    os.replace(src, dst)
                    quarantined.append(str(dst.relative_to(self.root)))
                tel.count("store.quarantined")
                del self._records[key]
            survivors = "".join(json.dumps(r.asdict()) + "\n" for r in self.records())
            tmp = self.index_path.with_suffix(".jsonl.tmp")
            tmp.write_text(survivors)
            os.replace(tmp, self.index_path)
        return VerifyStats(
            n_records=n_records,
            n_ok=n_records - len(corrupt),
            n_unchecksummed=n_unchecksummed,
            corrupt=corrupt,
            quarantined=quarantined,
            repaired=repair,
            deep=deep,
        )

    # -- parity --------------------------------------------------------------

    def parity(self, other: "RunStore") -> dict[str, str]:
        """Bitwise payload comparison against ``other`` on the shared keys.

        Returns ``{run_key: reason}`` for every divergence (empty = parity).
        Array entries must match bit for bit; the JSON header is compared
        after dropping wall-clock fields (``wall_s``, ``timings``, per-cell
        ``wall_s``) that legitimately differ between runs.  The chaos CI job
        uses this to assert a faulted-then-repaired store converges to the
        never-faulted baseline.
        """
        mismatches: dict[str, str] = {}
        shared = sorted(set(self._records) & set(other._records))
        for key in shared:
            try:
                mine = dict(np.load(io.BytesIO(self._read_verified(self._records[key]))))
                theirs = dict(np.load(io.BytesIO(other._read_verified(other._records[key]))))
            except StoreCorruptionError as e:
                mismatches[key] = f"corrupt: {e.reason}"
                continue
            if set(mine) != set(theirs):
                mismatches[key] = (
                    f"entry sets differ: {sorted(set(mine) ^ set(theirs))}"
                )
                continue
            for name in sorted(mine):
                if name == "header":
                    if _comparable_header(mine[name]) != _comparable_header(theirs[name]):
                        mismatches[key] = "header differs beyond wall-clock fields"
                        break
                elif not np.array_equal(mine[name], theirs[name]):
                    mismatches[key] = f"array {name!r} differs"
                    break
        return mismatches


def _comparable_header(header_entry: np.ndarray) -> dict:
    """A payload header with wall-clock fields stripped, for parity checks."""
    header = json.loads(str(header_entry[()]))
    for key in _VOLATILE_HEADER_KEYS:
        header.pop(key, None)
    for cell in header.get("cells", []):  # fleet SweepCells carry wall_s too
        if isinstance(cell, dict):
            cell.pop("wall_s", None)
    return header


# ---------------------------------------------------------------------------
# Summary metrics (index-row payload: the trend view reads only these)
# ---------------------------------------------------------------------------


def _engine_metrics(res: EngineResult) -> dict[str, float]:
    done = res.completed.astype(bool)
    mean_cost = float(np.mean(res.cost[done])) if done.any() else math.nan
    mean_time_h = float(np.mean(res.completion_time[done]) / 3600.0) if done.any() else math.nan
    return {
        "completion_rate": float(done.mean()),
        "mean_cost": mean_cost,
        "mean_completion_h": mean_time_h,
        "total_kills": float(res.n_kills.sum()),
        "total_checkpoints": float(res.n_checkpoints.sum()),
    }


def _fleet_metrics(grid: FleetGridResult) -> dict[str, float]:
    cells = grid.cells
    if not cells:
        return {"mean_total_cost": math.nan, "mean_kill_rate": math.nan, "completion_rate": math.nan}
    n_jobs = sum(c.n_jobs for c in cells)
    return {
        "mean_total_cost": float(np.mean([c.total_cost for c in cells])),
        "mean_kill_rate": float(np.mean([c.kill_rate for c in cells])),
        "completion_rate": sum(c.n_completed for c in cells) / max(1, n_jobs),
        "mean_migrations": float(np.mean([c.n_migrations for c in cells])),
    }


def _serving_metrics(res: ServingResult) -> dict[str, float]:
    with np.errstate(invalid="ignore"):
        finite_cost = res.cost_per_mreq[np.isfinite(res.cost_per_mreq)]
    return {
        "mean_availability": float(res.availability.mean()),
        "mean_slo_violation_s": float(res.slo_violation_s.mean()),
        "mean_cost_per_mreq": float(finite_cost.mean()) if finite_cost.size else math.nan,
        "total_preempted": float(res.n_preempted.sum()),
        "total_boot_lost": float(res.n_boot_lost.sum()),
    }


# ---------------------------------------------------------------------------
# Engine-result codec
# ---------------------------------------------------------------------------

_ENGINE_ARRAYS = (
    "completed",
    "completion_time",
    "cost",
    "n_checkpoints",
    "n_kills",
    "n_self_terminations",
    "work_lost_s",
)


def _pack_engine_result(scenario: Scenario, res: EngineResult) -> dict[str, np.ndarray]:
    header = {
        "engine": res.engine,
        "wall_s": res.wall_s,
        "bids": [float(b) for b in res.bids],
        "schemes": [s.value for s in res.schemes],
        "markets": [
            {"label": m.label, "seed": int(m.seed), "on_demand": float(m.on_demand)}
            for m in res.markets
        ],
        "timings": res.timings.asdict() if res.timings is not None else None,
        "scenario": scenario.canonical(),
    }
    out = {name: getattr(res, name) for name in _ENGINE_ARRAYS}
    out["header"] = np.array(json.dumps(header))
    return out


def _unpack_engine_result(z, scenario: Scenario | None) -> EngineResult:
    header = json.loads(str(z["header"][()]))
    timings = None
    if header["timings"] is not None:
        t = dict(header["timings"])
        t["per_scheme"] = {k: SchemePhases(**v) for k, v in t["per_scheme"].items()}
        timings = PhaseTimings(**t)
    return EngineResult(
        scenario=scenario,
        engine=str(header["engine"]),
        markets=[
            MarketCell(m["label"], int(m["seed"]), None, float(m["on_demand"]))
            for m in header["markets"]
        ],
        bids=tuple(float(b) for b in header["bids"]),
        schemes=tuple(Scheme(s) for s in header["schemes"]),
        wall_s=float(header["wall_s"]),
        timings=timings,
        **{name: z[name] for name in _ENGINE_ARRAYS},
    )


# ---------------------------------------------------------------------------
# Serving-result codec
# ---------------------------------------------------------------------------

_SERVING_ARRAYS = (
    "availability",
    "p99_latency_s",
    "slo_violation_s",
    "cost",
    "served_requests",
    "offered_requests",
    "cost_per_mreq",
    "n_preempted",
    "n_scale_out",
    "n_scale_in",
    "n_boot_lost",
    "capacity_rps",
    "spot_price",
    "rates",
)


def _pack_serving_result(scenario: ServingScenario, res: ServingResult) -> dict[str, np.ndarray]:
    header = {
        "engine": res.engine,
        "wall_s": res.wall_s,
        "policies": [str(p) for p in res.policies],
        "bid_margins": [float(m) for m in res.bid_margins],
        "seeds": [int(s) for s in res.seeds],
        "spot_types": [str(t) for t in res.spot_types],
        "scenario": scenario.canonical(),
    }
    out = {name: getattr(res, name) for name in _SERVING_ARRAYS}
    out["header"] = np.array(json.dumps(header))
    return out


def _unpack_serving_result(z) -> ServingResult:
    header = json.loads(str(z["header"][()]))
    return ServingResult(
        policies=tuple(str(p) for p in header["policies"]),
        bid_margins=tuple(float(m) for m in header["bid_margins"]),
        seeds=tuple(int(s) for s in header["seeds"]),
        spot_types=tuple(str(t) for t in header["spot_types"]),
        engine=str(header["engine"]),
        wall_s=float(header["wall_s"]),
        **{name: z[name] for name in _SERVING_ARRAYS},
    )


# ---------------------------------------------------------------------------
# Fleet-grid codec
# ---------------------------------------------------------------------------

_RECORD_COLUMNS = (
    ("job_id", np.int64),
    ("replica", np.int64),
    ("instance", None),  # unicode
    ("bid", np.float64),
    ("launch", np.float64),
    ("end", np.float64),
    ("termination", None),  # unicode enum value
    ("cost", np.float64),
    ("work_start", np.float64),
    ("initial_saved_ref", np.float64),
    ("saved_after_ref", np.float64),
    ("killed", np.bool_),
    ("completed", np.bool_),
    ("cancelled", np.bool_),
    ("self_terminated", np.bool_),
)


def _str_array(values: list[str]) -> np.ndarray:
    return np.array(values, dtype="U1") if not values else np.array(values)


def _job_dict(job: Job) -> dict:
    return {
        "id": job.id,
        "arrival_s": job.arrival_s,
        "work_s": job.work_s,
        "deadline_s": job.deadline_s,
        "sla": {
            "min_compute_units": job.sla.min_compute_units,
            "regions": list(job.sla.regions),
            "os": job.sla.os,
        },
    }


def _job_from_dict(d: Mapping[str, Any]) -> Job:
    return Job(
        id=int(d["id"]),
        arrival_s=float(d["arrival_s"]),
        work_s=float(d["work_s"]),
        deadline_s=None if d["deadline_s"] is None else float(d["deadline_s"]),
        sla=SLA(
            min_compute_units=float(d["sla"]["min_compute_units"]),
            regions=tuple(d["sla"]["regions"]),
            os=d["sla"]["os"],
        ),
    )


def _pack_fleet_grid(scenario: FleetScenario, grid: FleetGridResult) -> dict[str, np.ndarray]:
    payload: dict[str, np.ndarray] = {}
    results_meta = []
    for i, ((policy, margin, seed), res) in enumerate(sorted(grid.results.items())):
        index_of = {id(r): j for j, r in enumerate(res.records)}
        results_meta.append(
            {
                "key": [policy, margin, seed],
                "policy": res.policy,
                "scheme": res.scheme.value,
                "horizon": res.horizon,
                "outcomes": [
                    {
                        "job": _job_dict(o.job),
                        "completed": o.completed,
                        "completion_time": o.completion_time,
                        "cost": o.cost,
                        "n_kills": o.n_kills,
                        "n_migrations": o.n_migrations,
                        # attempts are shared with the records list: persist
                        # indices so reloading restores the same sharing
                        "attempts": [index_of[id(r)] for r in o.attempts],
                    }
                    for _, o in sorted(res.outcomes.items())
                ],
            }
        )
        for col, dtype in _RECORD_COLUMNS:
            values = [getattr(r, col) for r in res.records]
            if col == "termination":
                payload[f"r{i}_{col}"] = _str_array([v.value for v in values])
            elif dtype is None:
                payload[f"r{i}_{col}"] = _str_array([str(v) for v in values])
            else:
                payload[f"r{i}_{col}"] = np.array(values, dtype=dtype)
    header = {
        "wall_s": grid.wall_s,
        "cells": [dataclasses.asdict(c) for c in grid.cells],
        "results": results_meta,
        "scenario": scenario.canonical(),
    }
    payload["header"] = np.array(json.dumps(header))
    return payload


def _unpack_fleet_grid(z, scenario: FleetScenario | None) -> FleetGridResult:
    header = json.loads(str(z["header"][()]))
    results: dict[tuple[str, float, int], FleetResult] = {}
    for i, meta in enumerate(header["results"]):
        cols = {col: z[f"r{i}_{col}"] for col, _ in _RECORD_COLUMNS}
        n = len(cols["job_id"])
        records = [
            AttemptRecord(
                job_id=int(cols["job_id"][j]),
                replica=int(cols["replica"][j]),
                instance=str(cols["instance"][j]),
                bid=float(cols["bid"][j]),
                launch=float(cols["launch"][j]),
                end=float(cols["end"][j]),
                termination=Termination(str(cols["termination"][j])),
                cost=float(cols["cost"][j]),
                work_start=float(cols["work_start"][j]),
                initial_saved_ref=float(cols["initial_saved_ref"][j]),
                saved_after_ref=float(cols["saved_after_ref"][j]),
                killed=bool(cols["killed"][j]),
                completed=bool(cols["completed"][j]),
                cancelled=bool(cols["cancelled"][j]),
                self_terminated=bool(cols["self_terminated"][j]),
            )
            for j in range(n)
        ]
        outcomes: dict[int, JobOutcome] = {}
        for o in meta["outcomes"]:
            job = _job_from_dict(o["job"])
            outcomes[job.id] = JobOutcome(
                job=job,
                completed=bool(o["completed"]),
                completion_time=float(o["completion_time"]),
                cost=float(o["cost"]),
                n_kills=int(o["n_kills"]),
                n_migrations=int(o["n_migrations"]),
                attempts=[records[j] for j in o["attempts"]],
            )
        policy, margin, seed = meta["key"]
        results[(str(policy), float(margin), int(seed))] = FleetResult(
            policy=str(meta["policy"]),
            scheme=Scheme(meta["scheme"]),
            outcomes=outcomes,
            records=records,
            horizon=float(meta["horizon"]),
        )
    return FleetGridResult(
        scenario=scenario,
        cells=[SweepCell(**c) for c in header["cells"]],
        results=results,
        wall_s=float(header["wall_s"]),
    )
