"""Content-addressed run store: JSONL index + per-run npz payloads.

Layout (default root ``results/store/``, gitignored)::

    results/store/
      index.jsonl          # one RunRecord per line, append-only
      runs/<run_key>.npz   # the result payload, one file per run

The index is the queryable surface — every line carries the run key, the
scenario content hash, engine id, schema version, git sha, creation time,
wall time, and a small summary-metrics dict — so listing and trend analysis
never open a payload.  Payloads are plain ``npz`` archives (structure-of-
arrays outcome grids for :class:`~repro.engine.base.EngineResult`, per-cell
attempt-record columns for fleet grids) with one JSON header entry; floats
ride either in float64 arrays or through JSON's exact shortest-round-trip
repr, so a store round trip is bit-for-bit.

Crash safety: the payload is written to a temp file and renamed, and the
index line is appended (and flushed) only afterwards — an interrupted run
leaves either a complete entry or no entry, never a torn one.  Re-appending
the same key later simply supersedes the older line (last wins on load);
:meth:`RunStore.gc` compacts superseded lines away and deletes payload
files nothing references (``repro-suite gc``).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import pathlib
import subprocess
import time
from typing import Any, Mapping

import numpy as np

from repro.core.billing import Termination
from repro.core.provision import SLA
from repro.core.schemes import Scheme
from repro.core.simulator import SimResult  # noqa: F401  (documented payload scope)
from repro.engine.base import EngineResult, PhaseTimings, SchemePhases
from repro.engine.fleetgrid import FleetGridResult
from repro.engine.scenario import FleetScenario, MarketCell, Scenario
from repro.fleet.controller import AttemptRecord, FleetResult, JobOutcome
from repro.fleet.sweep import SweepCell
from repro.fleet.workload import Job
from repro.suite.hashing import SCHEMA_VERSION, run_key, scenario_hash

__all__ = ["GcStats", "RunRecord", "RunStore", "DEFAULT_ROOT"]

DEFAULT_ROOT = "results/store"


def _git_sha() -> str | None:
    """Current commit sha, or None outside a usable git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True, timeout=10
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    return out.stdout.strip() if out.returncode == 0 else None


@dataclasses.dataclass(frozen=True)
class RunRecord:
    """One index line: everything about a run except its bulk payload."""

    run_key: str
    scenario_hash: str
    engine: str
    schema_version: int
    kind: str  # "scenario" | "fleet"
    created_at: float  # unix seconds
    sha: str | None  # git commit the run was produced at
    payload: str  # path relative to the store root
    wall_s: float
    n_cells: int
    metrics: dict[str, float]
    suite: str | None = None
    cell: str | None = None

    def asdict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RunRecord":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass(frozen=True)
class GcStats:
    """What :meth:`RunStore.gc` reclaimed (or would reclaim, on a dry run)."""

    index_lines_before: int
    index_lines_after: int
    index_bytes_reclaimed: int
    payloads_deleted: list[str]  # store-relative paths
    payload_bytes_reclaimed: int
    dry_run: bool

    @property
    def bytes_reclaimed(self) -> int:
        return self.index_bytes_reclaimed + self.payload_bytes_reclaimed

    def summary(self) -> str:
        verb = "would reclaim" if self.dry_run else "reclaimed"
        return (
            f"index: {self.index_lines_before} -> {self.index_lines_after} lines; "
            f"{len(self.payloads_deleted)} orphaned payloads; "
            f"{verb} {self.bytes_reclaimed} bytes"
        )


class RunStore:
    """A persistent, content-addressed database of simulation runs."""

    def __init__(self, root: str | pathlib.Path = DEFAULT_ROOT):
        self.root = pathlib.Path(root)
        self.index_path = self.root / "index.jsonl"
        self.runs_dir = self.root / "runs"
        self._records: dict[str, RunRecord] = {}
        self._sha: str | None | bool = False  # False = not yet resolved
        self.reload()

    # -- index --------------------------------------------------------------

    def reload(self) -> None:
        """Re-read the index from disk (last line wins per key)."""
        self._records = {}
        if not self.index_path.exists():
            return
        for line in self.index_path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rec = RunRecord.from_dict(json.loads(line))
            except (json.JSONDecodeError, TypeError):
                continue  # torn/foreign line: ignorable, the payload re-runs
            self._records[rec.run_key] = rec

    def records(self) -> list[RunRecord]:
        """All index entries, oldest first."""
        return sorted(self._records.values(), key=lambda r: r.created_at)

    def get(self, key: str) -> RunRecord | None:
        return self._records.get(key)

    def has(self, key: str) -> bool:
        """True when the key is indexed *and* its payload file exists."""
        rec = self._records.get(key)
        return rec is not None and (self.root / rec.payload).exists()

    def __contains__(self, key: str) -> bool:
        return self.has(key)

    def __len__(self) -> int:
        return len(self._records)

    def _resolve_sha(self, sha: str | None) -> str | None:
        if sha is not None:
            return sha
        if self._sha is False:
            self._sha = _git_sha()
        return self._sha

    def _flush(self, rec: RunRecord, payload: dict[str, np.ndarray]) -> RunRecord:
        """Write payload-then-index (the interrupt-safety order)."""
        self.runs_dir.mkdir(parents=True, exist_ok=True)
        final = self.root / rec.payload
        tmp = final.with_suffix(".tmp.npz")
        with tmp.open("wb") as f:
            np.savez_compressed(f, **payload)
        os.replace(tmp, final)
        with self.index_path.open("a") as f:
            f.write(json.dumps(rec.asdict()) + "\n")
            f.flush()
        self._records[rec.run_key] = rec
        return rec

    # -- maintenance --------------------------------------------------------

    def gc(self, *, dry_run: bool = False) -> "GcStats":
        """Compact the index and delete orphaned payloads.

        The append-only index accumulates one superseded line per re-run of
        a key, and a superseded payload (or a run whose index append was
        interrupted) leaves an ``npz`` nothing references.  ``gc`` rewrites
        the index with only the surviving record per key (oldest first, via
        tmp-file + ``os.replace`` so a crash leaves the old or the new index,
        never a torn one) and unlinks every file under ``runs/`` no surviving
        record points to — including stale ``.tmp.npz`` leftovers.

        ``dry_run=True`` reports what would be reclaimed without touching
        disk.  Returns :class:`GcStats`.
        """
        self.reload()
        lines_before = 0
        index_bytes_before = 0
        if self.index_path.exists():
            text = self.index_path.read_text()
            index_bytes_before = len(text.encode())
            lines_before = sum(1 for ln in text.splitlines() if ln.strip())
        recs = self.records()
        new_text = "".join(json.dumps(r.asdict()) + "\n" for r in recs)
        referenced = {(self.root / r.payload).resolve() for r in recs}
        orphans = []
        if self.runs_dir.is_dir():
            orphans = sorted(
                p for p in self.runs_dir.glob("*.npz") if p.resolve() not in referenced
            )
        payload_bytes = sum(p.stat().st_size for p in orphans)
        if not dry_run:
            if self.index_path.exists():
                tmp = self.index_path.with_suffix(".jsonl.tmp")
                tmp.write_text(new_text)
                os.replace(tmp, self.index_path)
            for p in orphans:
                p.unlink()
        return GcStats(
            index_lines_before=lines_before,
            index_lines_after=len(recs),
            index_bytes_reclaimed=index_bytes_before - len(new_text.encode()),
            payloads_deleted=[str(p.relative_to(self.root)) for p in orphans],
            payload_bytes_reclaimed=payload_bytes,
            dry_run=dry_run,
        )

    # -- put ----------------------------------------------------------------

    def put_engine_result(
        self,
        scenario: Scenario,
        result: EngineResult,
        *,
        engine: str | None = None,
        suite: str | None = None,
        cell: str | None = None,
        sha: str | None = None,
    ) -> RunRecord:
        """Persist one single-scenario run; returns its index record."""
        engine = engine or result.engine
        key = run_key(scenario, engine)
        rec = RunRecord(
            run_key=key,
            scenario_hash=scenario_hash(scenario),
            engine=engine,
            schema_version=SCHEMA_VERSION,
            kind="scenario",
            created_at=time.time(),
            sha=self._resolve_sha(sha),
            payload=f"runs/{key}.npz",
            wall_s=float(result.wall_s),
            n_cells=result.n_cells,
            metrics=_engine_metrics(result),
            suite=suite,
            cell=cell,
        )
        return self._flush(rec, _pack_engine_result(scenario, result))

    def put_fleet_result(
        self,
        scenario: FleetScenario,
        grid: FleetGridResult,
        *,
        suite: str | None = None,
        cell: str | None = None,
        sha: str | None = None,
    ) -> RunRecord:
        """Persist one fleet-grid run (engine id ``"fleet"``: the scalar
        controller is the only fleet backend)."""
        key = run_key(scenario, "fleet")
        rec = RunRecord(
            run_key=key,
            scenario_hash=scenario_hash(scenario),
            engine="fleet",
            schema_version=SCHEMA_VERSION,
            kind="fleet",
            created_at=time.time(),
            sha=self._resolve_sha(sha),
            payload=f"runs/{key}.npz",
            wall_s=float(grid.wall_s),
            n_cells=len(grid.cells),
            metrics=_fleet_metrics(grid),
            suite=suite,
            cell=cell,
        )
        return self._flush(rec, _pack_fleet_grid(scenario, grid))

    # -- load ---------------------------------------------------------------

    def load(
        self,
        record_or_key: RunRecord | str,
        scenario: Scenario | FleetScenario | None = None,
    ) -> EngineResult | FleetGridResult:
        """Reconstruct a stored result.

        Pass the materialized ``scenario`` when you have it (the runner
        does) to get it attached to the result; without it the result's
        ``scenario`` is ``None`` and market cells carry no trace — the
        outcome arrays and metadata are complete either way.  Engine-result
        payloads store the SoA grid only: per-run ``sim_results`` lists (a
        reference-engine debugging aid) are not persisted.
        """
        rec = record_or_key if isinstance(record_or_key, RunRecord) else self._records[record_or_key]
        with np.load(self.root / rec.payload) as z:
            if rec.kind == "fleet":
                return _unpack_fleet_grid(z, scenario)
            return _unpack_engine_result(z, scenario)


# ---------------------------------------------------------------------------
# Summary metrics (index-row payload: the trend view reads only these)
# ---------------------------------------------------------------------------


def _engine_metrics(res: EngineResult) -> dict[str, float]:
    done = res.completed.astype(bool)
    mean_cost = float(np.mean(res.cost[done])) if done.any() else math.nan
    mean_time_h = float(np.mean(res.completion_time[done]) / 3600.0) if done.any() else math.nan
    return {
        "completion_rate": float(done.mean()),
        "mean_cost": mean_cost,
        "mean_completion_h": mean_time_h,
        "total_kills": float(res.n_kills.sum()),
        "total_checkpoints": float(res.n_checkpoints.sum()),
    }


def _fleet_metrics(grid: FleetGridResult) -> dict[str, float]:
    cells = grid.cells
    if not cells:
        return {"mean_total_cost": math.nan, "mean_kill_rate": math.nan, "completion_rate": math.nan}
    n_jobs = sum(c.n_jobs for c in cells)
    return {
        "mean_total_cost": float(np.mean([c.total_cost for c in cells])),
        "mean_kill_rate": float(np.mean([c.kill_rate for c in cells])),
        "completion_rate": sum(c.n_completed for c in cells) / max(1, n_jobs),
        "mean_migrations": float(np.mean([c.n_migrations for c in cells])),
    }


# ---------------------------------------------------------------------------
# Engine-result codec
# ---------------------------------------------------------------------------

_ENGINE_ARRAYS = (
    "completed",
    "completion_time",
    "cost",
    "n_checkpoints",
    "n_kills",
    "n_self_terminations",
    "work_lost_s",
)


def _pack_engine_result(scenario: Scenario, res: EngineResult) -> dict[str, np.ndarray]:
    header = {
        "engine": res.engine,
        "wall_s": res.wall_s,
        "bids": [float(b) for b in res.bids],
        "schemes": [s.value for s in res.schemes],
        "markets": [
            {"label": m.label, "seed": int(m.seed), "on_demand": float(m.on_demand)}
            for m in res.markets
        ],
        "timings": res.timings.asdict() if res.timings is not None else None,
        "scenario": scenario.canonical(),
    }
    out = {name: getattr(res, name) for name in _ENGINE_ARRAYS}
    out["header"] = np.array(json.dumps(header))
    return out


def _unpack_engine_result(z, scenario: Scenario | None) -> EngineResult:
    header = json.loads(str(z["header"][()]))
    timings = None
    if header["timings"] is not None:
        t = dict(header["timings"])
        t["per_scheme"] = {k: SchemePhases(**v) for k, v in t["per_scheme"].items()}
        timings = PhaseTimings(**t)
    return EngineResult(
        scenario=scenario,
        engine=str(header["engine"]),
        markets=[
            MarketCell(m["label"], int(m["seed"]), None, float(m["on_demand"]))
            for m in header["markets"]
        ],
        bids=tuple(float(b) for b in header["bids"]),
        schemes=tuple(Scheme(s) for s in header["schemes"]),
        wall_s=float(header["wall_s"]),
        timings=timings,
        **{name: z[name] for name in _ENGINE_ARRAYS},
    )


# ---------------------------------------------------------------------------
# Fleet-grid codec
# ---------------------------------------------------------------------------

_RECORD_COLUMNS = (
    ("job_id", np.int64),
    ("replica", np.int64),
    ("instance", None),  # unicode
    ("bid", np.float64),
    ("launch", np.float64),
    ("end", np.float64),
    ("termination", None),  # unicode enum value
    ("cost", np.float64),
    ("work_start", np.float64),
    ("initial_saved_ref", np.float64),
    ("saved_after_ref", np.float64),
    ("killed", np.bool_),
    ("completed", np.bool_),
    ("cancelled", np.bool_),
    ("self_terminated", np.bool_),
)


def _str_array(values: list[str]) -> np.ndarray:
    return np.array(values, dtype="U1") if not values else np.array(values)


def _job_dict(job: Job) -> dict:
    return {
        "id": job.id,
        "arrival_s": job.arrival_s,
        "work_s": job.work_s,
        "deadline_s": job.deadline_s,
        "sla": {
            "min_compute_units": job.sla.min_compute_units,
            "regions": list(job.sla.regions),
            "os": job.sla.os,
        },
    }


def _job_from_dict(d: Mapping[str, Any]) -> Job:
    return Job(
        id=int(d["id"]),
        arrival_s=float(d["arrival_s"]),
        work_s=float(d["work_s"]),
        deadline_s=None if d["deadline_s"] is None else float(d["deadline_s"]),
        sla=SLA(
            min_compute_units=float(d["sla"]["min_compute_units"]),
            regions=tuple(d["sla"]["regions"]),
            os=d["sla"]["os"],
        ),
    )


def _pack_fleet_grid(scenario: FleetScenario, grid: FleetGridResult) -> dict[str, np.ndarray]:
    payload: dict[str, np.ndarray] = {}
    results_meta = []
    for i, ((policy, margin, seed), res) in enumerate(sorted(grid.results.items())):
        index_of = {id(r): j for j, r in enumerate(res.records)}
        results_meta.append(
            {
                "key": [policy, margin, seed],
                "policy": res.policy,
                "scheme": res.scheme.value,
                "horizon": res.horizon,
                "outcomes": [
                    {
                        "job": _job_dict(o.job),
                        "completed": o.completed,
                        "completion_time": o.completion_time,
                        "cost": o.cost,
                        "n_kills": o.n_kills,
                        "n_migrations": o.n_migrations,
                        # attempts are shared with the records list: persist
                        # indices so reloading restores the same sharing
                        "attempts": [index_of[id(r)] for r in o.attempts],
                    }
                    for _, o in sorted(res.outcomes.items())
                ],
            }
        )
        for col, dtype in _RECORD_COLUMNS:
            values = [getattr(r, col) for r in res.records]
            if col == "termination":
                payload[f"r{i}_{col}"] = _str_array([v.value for v in values])
            elif dtype is None:
                payload[f"r{i}_{col}"] = _str_array([str(v) for v in values])
            else:
                payload[f"r{i}_{col}"] = np.array(values, dtype=dtype)
    header = {
        "wall_s": grid.wall_s,
        "cells": [dataclasses.asdict(c) for c in grid.cells],
        "results": results_meta,
        "scenario": scenario.canonical(),
    }
    payload["header"] = np.array(json.dumps(header))
    return payload


def _unpack_fleet_grid(z, scenario: FleetScenario | None) -> FleetGridResult:
    header = json.loads(str(z["header"][()]))
    results: dict[tuple[str, float, int], FleetResult] = {}
    for i, meta in enumerate(header["results"]):
        cols = {col: z[f"r{i}_{col}"] for col, _ in _RECORD_COLUMNS}
        n = len(cols["job_id"])
        records = [
            AttemptRecord(
                job_id=int(cols["job_id"][j]),
                replica=int(cols["replica"][j]),
                instance=str(cols["instance"][j]),
                bid=float(cols["bid"][j]),
                launch=float(cols["launch"][j]),
                end=float(cols["end"][j]),
                termination=Termination(str(cols["termination"][j])),
                cost=float(cols["cost"][j]),
                work_start=float(cols["work_start"][j]),
                initial_saved_ref=float(cols["initial_saved_ref"][j]),
                saved_after_ref=float(cols["saved_after_ref"][j]),
                killed=bool(cols["killed"][j]),
                completed=bool(cols["completed"][j]),
                cancelled=bool(cols["cancelled"][j]),
                self_terminated=bool(cols["self_terminated"][j]),
            )
            for j in range(n)
        ]
        outcomes: dict[int, JobOutcome] = {}
        for o in meta["outcomes"]:
            job = _job_from_dict(o["job"])
            outcomes[job.id] = JobOutcome(
                job=job,
                completed=bool(o["completed"]),
                completion_time=float(o["completion_time"]),
                cost=float(o["cost"]),
                n_kills=int(o["n_kills"]),
                n_migrations=int(o["n_migrations"]),
                attempts=[records[j] for j in o["attempts"]],
            )
        policy, margin, seed = meta["key"]
        results[(str(policy), float(margin), int(seed))] = FleetResult(
            policy=str(meta["policy"]),
            scheme=Scheme(meta["scheme"]),
            outcomes=outcomes,
            records=records,
            horizon=float(meta["horizon"]),
        )
    return FleetGridResult(
        scenario=scenario,
        cells=[SweepCell(**c) for c in header["cells"]],
        results=results,
        wall_s=float(header["wall_s"]),
    )
