"""Layered configuration: ordered override stacks with per-field provenance.

A suite resolves every cell's configuration from a stack of *layers* —
``base`` (an ``extends``-ed spec file) ← ``suite`` (the suite file's own
``[base]`` table) ← ``cell`` (one axis-product point or explicit ``[[cells]]``
table) ← ``cli`` (``--set key=value`` overrides) — the lib_layered_config
idiom.  :func:`merge_layers` deep-merges the stack (later layers win per
leaf; tables merge, lists replace wholesale) and records, for every dotted
leaf key, *which layer set it*.  That provenance is what ``repro-suite run
--dry-run`` prints next to each expanded cell, so a thousand-cell sweep can
be audited field by field without simulating anything.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping, Sequence

__all__ = [
    "Layer",
    "Resolved",
    "merge_layers",
    "nest_dotted",
    "parse_override",
    "parse_value",
]


@dataclasses.dataclass(frozen=True)
class Layer:
    """One named override layer: a (possibly nested) mapping of fields."""

    name: str
    values: Mapping[str, Any]


@dataclasses.dataclass(frozen=True)
class Resolved:
    """A merged configuration plus per-leaf provenance.

    ``provenance`` maps dotted leaf keys (``"params.t_c"``) to the name of
    the layer that last set them; keys a merge never touched (dataclass
    defaults) simply do not appear and report as ``"default"``.
    """

    values: dict[str, Any]
    provenance: dict[str, str]

    def origin(self, dotted: str) -> str:
        return self.provenance.get(dotted, "default")


def merge_layers(layers: Sequence[Layer]) -> Resolved:
    """Deep-merge ``layers`` in order (later wins) with provenance.

    Nested mappings merge key-by-key; every other value — scalars *and*
    lists — replaces the previous one wholesale.  Replacing a table with a
    scalar (or vice versa) drops the stale subtree and its provenance.
    """
    values: dict[str, Any] = {}
    provenance: dict[str, str] = {}
    for layer in layers:
        _merge_into(values, provenance, layer.values, layer.name, prefix="")
    return Resolved(values=values, provenance=provenance)


def _drop_subtree(provenance: dict[str, str], dotted: str) -> None:
    stale = [k for k in provenance if k == dotted or k.startswith(dotted + ".")]
    for k in stale:
        del provenance[k]


def _merge_into(
    dst: dict[str, Any],
    provenance: dict[str, str],
    src: Mapping[str, Any],
    layer_name: str,
    prefix: str,
) -> None:
    for key, value in src.items():
        dotted = prefix + key
        if isinstance(value, Mapping):
            node = dst.get(key)
            if not isinstance(node, dict):
                _drop_subtree(provenance, dotted)
                node = dst[key] = {}
            _merge_into(node, provenance, value, layer_name, dotted + ".")
        else:
            _drop_subtree(provenance, dotted)
            dst[key] = list(value) if isinstance(value, (list, tuple)) else value
            provenance[dotted] = layer_name


def nest_dotted(flat: Mapping[str, Any]) -> dict[str, Any]:
    """Lift ``{"params.t_c": 120}`` into ``{"params": {"t_c": 120}}``."""
    out: dict[str, Any] = {}
    for dotted, value in flat.items():
        node = out
        parts = dotted.split(".")
        for part in parts[:-1]:
            nxt = node.setdefault(part, {})
            if not isinstance(nxt, dict):
                raise ValueError(f"override {dotted!r} descends through non-table key {part!r}")
            node = nxt
        node[parts[-1]] = value
    return out


def parse_value(text: str) -> Any:
    """Parse one override value: JSON literal if it is one, else the raw
    string (so ``--set scheme=hour`` needs no quoting)."""
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        return text


def parse_override(item: str) -> tuple[str, Any]:
    """Split one ``--set key.path=value`` argument."""
    key, sep, raw = item.partition("=")
    if not sep or not key:
        raise ValueError(f"override {item!r} is not of the form key=value")
    return key.strip(), parse_value(raw.strip())
