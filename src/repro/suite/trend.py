"""Trend view: metric drift per scenario hash across git history.

The run store accumulates runs of the *same* scenario content produced at
different commits (a re-run only happens when the schema version or engine
id changes the key, or the store was produced on another sha before the
cell was cached — plus explicit ``--rerun``-style invalidations by bumping
:data:`repro.suite.hashing.SCHEMA_VERSION`).  :func:`compute_trends` groups
the index by ``(scenario_hash, engine)``, orders each group by creation
time, and reports how every summary metric moved between the first and the
latest run — with the git shas involved, and, where
``BENCH_history.jsonl`` (written by ``benchmarks/engine_bench.py``) has an
entry for those shas, the backend speedups measured at the same commit.
That joins *what the simulation says* with *how fast the backends ran it*
per sha: a metric drift with an unchanged bench points at semantics, a
bench regression with unchanged metrics at performance.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import math
import pathlib
from typing import Any, Mapping, Sequence

from repro.suite.store import RunRecord, RunStore

__all__ = ["TrendGroup", "compute_trends", "load_bench_history", "render_trends", "trend_report"]

log = logging.getLogger("repro.suite.trend")

DEFAULT_HISTORY = "BENCH_history.jsonl"


def load_bench_history(path: str | pathlib.Path = DEFAULT_HISTORY) -> dict[str, dict]:
    """``sha -> bench record`` from BENCH_history.jsonl (last run per sha wins)."""
    p = pathlib.Path(path)
    out: dict[str, dict] = {}
    if not p.exists():
        return out
    for line in p.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            row = json.loads(line)
        except json.JSONDecodeError:
            log.warning("skipping malformed bench history line: %.80s", line)
            continue
        if row.get("sha"):
            out[row["sha"]] = row
    return out


def _speedups(bench: Mapping[str, Any] | None) -> dict[str, float]:
    if not bench:
        return {}
    return {
        name: entry["speedup"]
        for name, entry in bench.get("backends", {}).items()
        if entry.get("speedup") is not None
    }


@dataclasses.dataclass(frozen=True)
class TrendGroup:
    """All stored runs of one (scenario content, engine) identity."""

    scenario_hash: str
    engine: str
    kind: str
    suite: str | None  # most recent non-null suite label
    runs: tuple[RunRecord, ...]  # ordered oldest -> newest

    @property
    def shas(self) -> list[str | None]:
        return [r.sha for r in self.runs]

    @property
    def first(self) -> RunRecord:
        return self.runs[0]

    @property
    def last(self) -> RunRecord:
        return self.runs[-1]

    def drift(self) -> dict[str, tuple[float, float, float]]:
        """Per-metric ``(first, last, delta)`` between oldest and newest run."""
        out: dict[str, tuple[float, float, float]] = {}
        for name, last_v in self.last.metrics.items():
            first_v = self.first.metrics.get(name)
            if first_v is None:
                continue
            delta = last_v - first_v
            if math.isnan(last_v) and math.isnan(first_v):
                delta = 0.0
            out[name] = (first_v, last_v, delta)
        return out

    def bench_join(self, bench_by_sha: Mapping[str, dict]) -> dict[str, dict[str, float]]:
        """Backend speedups measured at this group's first/last shas."""
        out = {}
        for which, rec in (("first", self.first), ("last", self.last)):
            sp = _speedups(bench_by_sha.get(rec.sha or ""))
            if sp:
                out[which] = sp
        return out


def compute_trends(
    records: Sequence[RunRecord], bench_by_sha: Mapping[str, dict] | None = None
) -> list[TrendGroup]:
    """Group index records by scenario identity, oldest-first within groups."""
    groups: dict[tuple[str, str], list[RunRecord]] = {}
    for rec in sorted(records, key=lambda r: r.created_at):
        groups.setdefault((rec.scenario_hash, rec.engine), []).append(rec)
    out = []
    for (shash, engine), runs in sorted(groups.items()):
        suite = next((r.suite for r in reversed(runs) if r.suite), None)
        out.append(
            TrendGroup(
                scenario_hash=shash,
                engine=engine,
                kind=runs[-1].kind,
                suite=suite,
                runs=tuple(runs),
            )
        )
    return out


def _fmt_delta(first: float, last: float, delta: float) -> str:
    if math.isnan(delta):
        return "nan"
    if delta == 0.0:
        return "unchanged"
    pct = f" ({delta / first:+.2%})" if first and not math.isnan(first) else ""
    return f"{first:.4g} -> {last:.4g}{pct}"


def render_trends(
    groups: Sequence[TrendGroup], bench_by_sha: Mapping[str, dict] | None = None
) -> str:
    """Plain-text trend report (one block per scenario identity)."""
    bench_by_sha = bench_by_sha or {}
    if not groups:
        return "# trend: empty run store"
    lines = [f"# trend: {len(groups)} scenario identities"]
    for g in groups:
        label = f" suite={g.suite}" if g.suite else ""
        lines.append(
            f"{g.scenario_hash[:12]} engine={g.engine} kind={g.kind}{label} "
            f"runs={len(g.runs)} shas={[s[:9] if s else None for s in dict.fromkeys(g.shas)]}"
        )
        if len(g.runs) < 2:
            lines.append("    single run — no drift to report")
        else:
            for name, (first, last, delta) in sorted(g.drift().items()):
                lines.append(f"    {name:<18} {_fmt_delta(first, last, delta)}")
        joined = g.bench_join(bench_by_sha)
        for which, speedups in joined.items():
            sp = "  ".join(f"{k}={v:.1f}x" for k, v in sorted(speedups.items()))
            lines.append(f"    bench@{which:<5} {sp}")
    return "\n".join(lines)


def trend_report(
    store: RunStore, history_path: str | pathlib.Path = DEFAULT_HISTORY
) -> str:
    """The ``repro-suite trend`` surface: store index x bench history."""
    bench = load_bench_history(history_path)
    return render_trends(compute_trends(store.records(), bench), bench)
