"""Deterministic resumable token pipeline.

Batches are a *pure function of (seed, step)* via counter-based hashing
(threefry through jax.random.fold_in), so the only iterator state is the
step counter — restoring a checkpoint restores the exact data order with no
buffered state to persist.  This is the property the paper's E_launch /
W_launch workflow needs: "Resume tasks" = restore params + opt state + one
integer.

Synthetic corpus mode: documents of geometric length separated by EOS, with
a Zipfian unigram distribution — enough structure for loss curves to be
meaningfully decreasing in the examples.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    eos: int = 0
    mean_doc_len: float = 64.0
    step: int = 0  # checkpointable state (the only state)

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])
        assert int(d["seed"]) == self.seed, "restoring a stream with a different seed"

    def batch_at(self, step: int) -> dict:
        """Pure: the batch for a given step (used for resume tests)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        # zipf-ish unigram: sample uniform in log-rank space
        u = jax.random.uniform(k1, (self.batch, self.seq_len + 1))
        ranks = jnp.exp(u * np.log(self.vocab_size - 1)).astype(jnp.int32)
        tokens = jnp.clip(ranks, 1, self.vocab_size - 1)
        # EOS boundaries with prob 1/mean_doc_len
        eos_mask = jax.random.uniform(k2, (self.batch, self.seq_len + 1)) < (1.0 / self.mean_doc_len)
        tokens = jnp.where(eos_mask, self.eos, tokens)
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
        }

    def __next__(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def __iter__(self):
        return self
