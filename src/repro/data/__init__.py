"""Deterministic, resumable data pipeline."""

from repro.data.pipeline import TokenStream

__all__ = ["TokenStream"]
