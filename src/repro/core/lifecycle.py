"""Application lifecycle FSM (paper Fig. 3).

Six states: New, Inactive, Active, Unbalanced, Unreachable, Terminated.
Healing transitions (Unbalanced/Unreachable -> Active) run the workflow the
monitoring subsystem maps to the triggering event.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable


class AppState(enum.Enum):
    NEW = "new"
    INACTIVE = "inactive"
    ACTIVE = "active"
    UNBALANCED = "unbalanced"
    UNREACHABLE = "unreachable"
    TERMINATED = "terminated"


_ALLOWED: dict[AppState, tuple[AppState, ...]] = {
    AppState.NEW: (AppState.INACTIVE,),
    AppState.INACTIVE: (AppState.ACTIVE, AppState.TERMINATED),
    AppState.ACTIVE: (
        AppState.INACTIVE,
        AppState.UNBALANCED,
        AppState.UNREACHABLE,
        AppState.TERMINATED,
    ),
    AppState.UNBALANCED: (AppState.ACTIVE, AppState.TERMINATED),
    AppState.UNREACHABLE: (AppState.ACTIVE, AppState.TERMINATED),
    AppState.TERMINATED: (),
}


@dataclasses.dataclass
class Lifecycle:
    state: AppState = AppState.NEW
    history: list[tuple[AppState, AppState]] = dataclasses.field(default_factory=list)
    on_transition: Callable[[AppState, AppState], None] | None = None

    def to(self, new: AppState) -> None:
        if new not in _ALLOWED[self.state]:
            raise ValueError(f"illegal transition {self.state.value} -> {new.value}")
        old, self.state = self.state, new
        self.history.append((old, new))
        if self.on_transition is not None:
            self.on_transition(old, new)

    # Convenience transitions mirroring Fig. 3
    def map_modules(self):
        self.to(AppState.INACTIVE)

    def deploy(self):
        self.to(AppState.ACTIVE)

    def overload(self):
        self.to(AppState.UNBALANCED)

    def resource_failure(self):
        self.to(AppState.UNREACHABLE)

    def heal(self):
        self.to(AppState.ACTIVE)

    def release(self):
        self.to(AppState.TERMINATED)
