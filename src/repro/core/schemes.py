"""Checkpointing schemes for spot instances (paper §V and §VI).

Five schemes from Yi et al. [3] re-simulated under corrected billing, plus the
paper's contribution, ACC:

  NONE  — never checkpoint; every out-of-bid kill restarts the job from zero.
  OPT   — oracle: a checkpoint completes exactly at each kill instant.
  HOUR  — a checkpoint completes exactly at each instance-hour boundary.
  EDGE  — a checkpoint starts at every rising edge of the spot price.
  ADAPT — at a fixed cadence, checkpoint iff the expected recovery time of
          skipping exceeds that of taking (hazard estimated from history).
  ACC   — the paper's Application-Centric Checkpointing: bid S_bid ~ infinity
          on the instance (never provider-killed) and make checkpoint /
          terminate decisions at the decision points of Eq. (3)-(4):
              t_cd = t_h - t_c - t_w      (checkpoint decision)
              t_td = t_h - t_w            (terminate decision)
          relative to each instance-hour boundary t_h, against the
          *application* bid A_bid.
"""

from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core.market import HOUR, PriceTrace


class Scheme(enum.Enum):
    NONE = "none"
    OPT = "opt"
    HOUR = "hour"
    EDGE = "edge"
    ADAPT = "adapt"
    ACC = "acc"


REALISTIC_SCHEMES = (Scheme.HOUR, Scheme.EDGE, Scheme.ADAPT, Scheme.ACC)
ALL_SCHEMES = tuple(Scheme)


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Simulation constants (defaults follow Yi et al.'s setup)."""

    t_c: float = 300.0  # checkpoint write time (s); model-size-aware in SpotTrainer
    t_r: float = 600.0  # restart/recovery overhead per (re)launch (s)
    t_w: float = 5.0  # spot-price query latency (s) — ACC decision points
    poll_s: float = 60.0  # relaunch polling period (user-defined, paper §VI-B)
    adapt_interval_s: float = 600.0  # ADAPT decision cadence
    billing_period_s: float = HOUR

    def __post_init__(self):
        assert self.t_c >= 0 and self.t_r >= 0 and self.t_w >= 0
        assert self.t_c + self.t_w < self.billing_period_s, "decision points must fall inside the hour"


# ---------------------------------------------------------------------------
# Empirical failure model (used by ADAPT here and by provision.Algorithm1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FailurePdf:
    """Empirical pdf of out-of-bid failure age, built from price history.

    ``pdf[k]`` is the probability that an availability period (for the given
    bid) lasts between ``k`` and ``k+1`` bins of ``bin_s`` seconds.  A period
    that survives to the trace horizon is censored and counted in the tail
    mass ``censored``.

    Survival queries go through a lazily-built *binned survival table*
    (:meth:`survival_table`), the shared numeric source for the scalar ADAPT
    loop, the provisioning math, and the batched ADAPT kernel
    (:mod:`repro.engine.kernels`) — one table, so the per-step "checkpoint
    now?" decision is the same bit pattern on every backend.
    """

    #: default binning of :meth:`from_trace` (one minute bins, a 7-day range)
    DEFAULT_BIN_S = 60.0
    DEFAULT_MAX_BINS = 7 * 24 * 60

    bin_s: float
    pdf: np.ndarray  # (K,)
    censored: float  # mass of periods that never failed in-history

    @staticmethod
    def from_trace(trace: PriceTrace, bid: float, bin_s: float = DEFAULT_BIN_S, max_bins: int = DEFAULT_MAX_BINS) -> "FailurePdf":
        periods = trace.available_periods(bid)
        durations = []
        censored_n = 0
        for a, b in periods:
            if b >= trace.horizon:  # censored: never observed to fail
                censored_n += 1
            else:
                durations.append(b - a)
        n = len(durations) + censored_n
        pdf = np.zeros(max_bins)
        if n == 0:
            return FailurePdf(bin_s=bin_s, pdf=pdf, censored=1.0)
        for d in durations:
            k = min(int(d / bin_s), max_bins - 1)
            pdf[k] += 1.0 / n
        return FailurePdf(bin_s=bin_s, pdf=pdf, censored=censored_n / n)

    def survival_table(self) -> np.ndarray:
        """``(K+1,)`` binned survival values: entry ``k < K`` is
        P(period outlives ``k`` full bins) = ``1 - cumsum(pdf)[k-1]``
        (``1.0`` at ``k=0``); entry ``K`` is the censored tail mass.

        Built once per pdf and cached — every :meth:`survival` query (and the
        batched ADAPT decision table derived from it) reads these exact
        floats, so scalar and lockstep hazard decisions can never diverge.
        """
        tab = getattr(self, "_survival_table", None)
        if tab is None:
            K = len(self.pdf)
            tab = np.empty(K + 1)
            tab[0] = 1.0
            tab[1:K] = 1.0 - np.cumsum(self.pdf)[: K - 1]
            tab[K] = self.censored
            object.__setattr__(self, "_survival_table", tab)  # frozen-safe cache
        return tab

    def compact_survival(self) -> tuple[np.ndarray, int]:
        """``(values, top)`` — the survival table with its constant plateau
        folded away.  ``values[k]`` for ``k <= top`` are the leading survival
        entries, ``values[top + 1]`` is the censored tail; ages binned past
        ``top`` (but below ``len(pdf)``) read the plateau value ``values[top]``
        because the cumulative sum is bitwise constant once the pdf runs out
        of mass.  This is what the batch/jax ADAPT kernels pack per (market,
        bid) cell — a 7-day pdf compresses from 10081 entries to the observed
        failure range.

        Cached per pdf like :meth:`survival_table`: every consumer in one
        process (scalar ADAPT, provisioning, the engine backends' decision
        tables) shares the same array object.
        """
        cached = getattr(self, "_compact_survival", None)
        if cached is None:
            tab = self.survival_table()
            K = len(self.pdf)
            nz = np.nonzero(self.pdf)[0]
            top = int(min(nz[-1] + 1 if nz.size else 0, K - 1))
            cached = np.concatenate([tab[: top + 1], [self.censored]]), top
            object.__setattr__(self, "_compact_survival", cached)  # frozen-safe
        return cached

    def survival(self, age_s: float) -> float:
        """P(period lasts longer than ``age_s``)."""
        k = int(age_s / self.bin_s)
        return float(self.survival_table()[min(k, len(self.pdf))])

    def hazard(self, age_s: float, window_s: float) -> float:
        """P(fail within ``window_s`` | survived to ``age_s``)."""
        s_now = self.survival(age_s)
        if s_now <= 0.0:
            return 1.0
        s_later = self.survival(age_s + window_s)
        return float(np.clip((s_now - s_later) / s_now, 0.0, 1.0))


def adapt_should_checkpoint(
    pdf: FailurePdf,
    age_s: float,
    unsaved_work_s: float,
    params: SimParams,
) -> bool:
    """Yi et al.'s ADAPT rule (expected-recovery-time comparison).

    Skipping risks re-doing ``unsaved_work_s`` plus a restart; taking costs
    ``t_c`` now.  Checkpoint iff the expected loss of skipping over the next
    decision window exceeds the certain cost of taking.
    """
    h = pdf.hazard(age_s, params.adapt_interval_s)
    expected_loss_skip = h * (unsaved_work_s + params.t_r)
    return expected_loss_skip > params.t_c


# ---------------------------------------------------------------------------
# ACC decision points (paper Eq. 3-4)
# ---------------------------------------------------------------------------


def decision_points(hour_boundary: float, params: SimParams) -> tuple[float, float]:
    """(t_cd, t_td) for one instance-hour boundary (Eq. 3 and Eq. 4)."""
    t_cd = hour_boundary - params.t_c - params.t_w
    t_td = hour_boundary - params.t_w
    return t_cd, t_td
