"""Event generation for spot instances (paper §VI-A).

Three events drive the monitoring->controller loop:

  * ``E_ckpt``      — take a checkpoint (fired at t_cd when price > A_bid),
  * ``E_terminate`` — self-terminate the instance (fired at t_td when price
                      is still > A_bid),
  * ``E_launch``    — (re)launch at the start of an available period.

plus the framework-level events of [2] (threshold / prediction / request /
ping / schedule based) represented as :class:`EventKind` so the same
monitoring subsystem serves both the simulator and the live SpotTrainer.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Iterator

from repro.core.market import PriceTrace
from repro.core.schemes import SimParams, decision_points
from repro.obs.telemetry import current as _obs_current


class EventKind(enum.Enum):
    # spot events (this paper)
    CKPT = "E_ckpt"
    TERMINATE = "E_terminate"
    LAUNCH = "E_launch"
    # framework events ([2])
    THRESHOLD = "E_threshold"
    PREDICTION = "E_prediction"
    REQUEST = "E_request"
    PING = "E_ping"
    SCHEDULE = "E_schedule"


@dataclasses.dataclass(frozen=True)
class Event:
    kind: EventKind
    time: float
    payload: dict


@dataclasses.dataclass
class SpotEventGenerator:
    """Generates E_ckpt / E_terminate / E_launch for one instance lease.

    This is the *runtime* counterpart of the simulator's ACC loop: the
    SpotTrainer drives it with wall-clock hour boundaries; tests drive it
    with a trace.  ``price_fn(t)`` abstracts "query current spot price"
    (latency t_w is accounted for by the decision-point math, Eq. 3-4).
    """

    a_bid: float
    params: SimParams
    price_fn: Callable[[float], float]

    def events_for_hour(self, hour_boundary: float) -> Iterator[Event]:
        t_cd, t_td = decision_points(hour_boundary, self.params)
        price_cd = self.price_fn(t_cd)
        if price_cd > self.a_bid:
            yield self._emit(
                Event(EventKind.CKPT, t_cd, {"price": price_cd, "deadline": hour_boundary})
            )
        price_td = self.price_fn(t_td)
        if price_td > self.a_bid:
            yield self._emit(
                Event(EventKind.TERMINATE, t_td, {"price": price_td, "at": hour_boundary})
            )

    def launch_event(self, t: float) -> Event | None:
        p = self.price_fn(t)
        if p <= self.a_bid:
            return self._emit(Event(EventKind.LAUNCH, t, {"price": p}))
        return None

    @staticmethod
    def _emit(ev: Event) -> Event:
        """Mirror a generated monitoring event onto the active telemetry
        collector (sim-time instant + counter), then pass it through."""
        tel = _obs_current()
        if tel.enabled:
            tel.event(ev.kind.value, ev.time, **ev.payload)
            tel.count(f"events.{ev.kind.value}")
        return ev


def trace_price_fn(trace: PriceTrace) -> Callable[[float], float]:
    return trace.price_at
