"""Unified application definition (paper Eq. 1-2 and the spot template Eq. 5-6).

    A = (T, R, R_m, P, U, M)          M = (E, W, E_m, W_m)

Tiers, resources, resource->tier mapping, policies, users, and a monitoring
subsystem of events, workflows and their mappings.  Workflows are ordered
action lists executed by the Controller through a pluggable action registry
(the live registry in ``repro.train.spot_trainer`` launches meshes, mounts
checkpoint volumes, saves/restores state; tests use recording stubs).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.events import EventKind


@dataclasses.dataclass(frozen=True)
class Tier:
    name: str


@dataclasses.dataclass(frozen=True)
class Resource:
    name: str
    provider: str  # "ec2" in the paper; "tpu" here
    type: str  # "spot_instance" | "EBS" | "pod_slice" | "ckpt_volume"
    size: str


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    spec: dict


@dataclasses.dataclass(frozen=True)
class Workflow:
    name: str
    actions: tuple[str, ...]  # action names resolved via the Controller registry


@dataclasses.dataclass(frozen=True)
class Monitoring:
    """M = (E, W, E_m, W_m)."""

    events: tuple[EventKind, ...]
    workflows: tuple[Workflow, ...]
    event_map: dict[EventKind, str]  # E_m : E -> resource name (or tier name)
    workflow_map: dict[str, EventKind]  # W_m : workflow name -> event

    def workflow_for(self, kind: EventKind) -> Workflow:
        for wf in self.workflows:
            if self.workflow_map.get(wf.name) == kind:
                return wf
        raise KeyError(f"no workflow mapped to {kind}")


@dataclasses.dataclass(frozen=True)
class Application:
    """A = (T, R, R_m, P, U, M)."""

    name: str
    tiers: tuple[Tier, ...]
    resources: tuple[Resource, ...]
    resource_map: dict[str, str]  # resource name -> tier name
    policies: tuple[Policy, ...]
    users: tuple[str, ...]
    monitoring: Monitoring

    def validate(self) -> None:
        tier_names = {t.name for t in self.tiers}
        res_names = {r.name for r in self.resources}
        for r, t in self.resource_map.items():
            if r not in res_names:
                raise ValueError(f"R_m maps unknown resource {r}")
            if t not in tier_names:
                raise ValueError(f"R_m maps to unknown tier {t}")
        wf_names = {w.name for w in self.monitoring.workflows}
        for wf, ev in self.monitoring.workflow_map.items():
            if wf not in wf_names:
                raise ValueError(f"W_m maps unknown workflow {wf}")
            if ev not in self.monitoring.events:
                raise ValueError(f"W_m maps {wf} to unregistered event {ev}")
        for ev, target in self.monitoring.event_map.items():
            if target not in res_names and target not in tier_names:
                raise ValueError(f"E_m maps {ev} to unknown target {target}")


def spot_application(
    name: str,
    instance_type: str,
    a_bid: float,
    s_bid: float,
    sla: dict | None = None,
    ckpt_volume_size: str = "1GB",
) -> Application:
    """The paper's Eq. 5-6 template: single tier, spot instance + EBS volume,
    the three spot events, and the four workflows W_start/W_ckpt/W_terminate/
    W_launch."""
    t1 = Tier("t1")
    r1 = Resource("r1", provider="ec2", type="spot_instance", size=instance_type)
    r2 = Resource("r2", provider="ec2", type="EBS", size=ckpt_volume_size)
    w_start = Workflow("W_start", ("launch_spot", "mount_volume", "copy_job", "start_job"))
    w_ckpt = Workflow("W_ckpt", ("save_results",))
    w_term = Workflow("W_terminate", ("terminate_spot",))
    w_launch = Workflow("W_launch", ("launch_spot", "mount_volume", "resume_tasks"))
    mon = Monitoring(
        events=(EventKind.CKPT, EventKind.TERMINATE, EventKind.LAUNCH),
        workflows=(w_start, w_ckpt, w_term, w_launch),
        event_map={
            EventKind.CKPT: "r1",
            EventKind.TERMINATE: "r1",
            EventKind.LAUNCH: "r1",
        },
        workflow_map={
            "W_ckpt": EventKind.CKPT,
            "W_terminate": EventKind.TERMINATE,
            "W_launch": EventKind.LAUNCH,
        },
    )
    app = Application(
        name=name,
        tiers=(t1,),
        resources=(r1, r2),
        resource_map={"r1": "t1", "r2": "t1"},
        policies=(
            Policy("sla", sla or {}),
            Policy("bids", {"A_bid": a_bid, "S_bid": s_bid}),
        ),
        users=("owner",),
        monitoring=mon,
    )
    app.validate()
    return app


class Controller:
    """Executes workflows through a registry of action handlers."""

    def __init__(self, registry: dict[str, Callable[..., None]]):
        self.registry = dict(registry)
        self.log: list[str] = []

    def execute(self, wf: Workflow, **ctx) -> None:
        for action in wf.actions:
            handler = self.registry.get(action)
            if handler is None:
                raise KeyError(f"no handler registered for action '{action}'")
            handler(**ctx)
            self.log.append(f"{wf.name}:{action}")
