"""Amazon EC2 spot billing rules (paper §IV), implemented exactly.

The paper's §VII explicitly *corrects* the billing model of Yi et al.'s
simulator: each instance-hour is charged at the spot price in effect at the
**beginning** of that instance-hour (hours are relative to instance launch),
not at the last observed price.  Additional rules:

  * the final partial hour is **free** iff the instance was terminated by the
    provider (out-of-bid);
  * the final partial hour is charged as a **full hour** (at its start price)
    if the user terminates the instance forcefully — job completion counts as
    a user termination;
  * a termination exactly on an hour boundary never starts (or pays) the next
    hour.

``billing_period_s`` generalizes the 3600 s instance-hour so EXPERIMENTS.md
can ablate modern per-minute billing.
"""

from __future__ import annotations

import dataclasses
import enum
import math

from repro.core.market import HOUR, PriceTrace


class Termination(enum.Enum):
    OUT_OF_BID = "out_of_bid"  # provider kill: partial hour free
    USER = "user"  # forced by user (incl. job completion): full hour charged


@dataclasses.dataclass(frozen=True)
class BillingItem:
    hour_start: float
    price: float
    charged: bool


def bill_run(
    trace: PriceTrace,
    launch: float,
    end: float,
    termination: Termination,
    billing_period_s: float = HOUR,
) -> list[BillingItem]:
    """Itemized bill for one instance run ``[launch, end)``.

    Returns one item per started billing period.  ``charged=False`` only on
    the final partial period of an out-of-bid kill.
    """
    if end < launch:
        raise ValueError(f"end {end} < launch {launch}")
    if end == launch:
        return []
    items: list[BillingItem] = []
    n_periods = int(math.ceil((end - launch) / billing_period_s - 1e-12))
    for k in range(n_periods):
        start = launch + k * billing_period_s
        full = start + billing_period_s <= end + 1e-9
        charged = full or termination == Termination.USER
        items.append(BillingItem(hour_start=start, price=trace.price_at(start), charged=charged))
    return items


def run_cost(
    trace: PriceTrace,
    launch: float,
    end: float,
    termination: Termination,
    billing_period_s: float = HOUR,
) -> float:
    return sum(i.price for i in bill_run(trace, launch, end, termination, billing_period_s) if i.charged)
