"""The paper's primary contribution: application-centric resource provisioning
and checkpointing for spot capacity.

  * market      — instance catalog + calibrated price traces
  * billing     — corrected EC2 spot billing (hour-start price, free partial hour)
  * schemes     — NONE/OPT/HOUR/EDGE/ADAPT + the paper's ACC, decision points
  * simulator   — discrete-event engine + bid sweeps (paper §VII)
  * provision   — Algorithm 1 (A_bid, instance_type via EET)
  * events      — E_ckpt / E_terminate / E_launch generation
  * appdef      — A=(T,R,Rm,P,U,M) unified definition + Controller
  * lifecycle   — six-state application FSM
"""

from repro.core.billing import Termination, bill_run, run_cost
from repro.core.events import Event, EventKind, SpotEventGenerator
from repro.core.lifecycle import AppState, Lifecycle
from repro.core.market import (
    HOUR,
    InstanceType,
    PriceTrace,
    TraceModel,
    catalog,
    constant_trace,
    ensemble_seed,
    get_instance,
    sample_traces_batch,
    shift_trace,
    step_trace,
    synthetic_trace,
    synthetic_traces_batch,
    trace_ensemble,
)
from repro.core.provision import SLA, ProvisioningDecision, algorithm1, expected_execution_time
from repro.core.appdef import Application, Controller, Monitoring, Workflow, spot_application
from repro.core.schemes import (
    ALL_SCHEMES,
    REALISTIC_SCHEMES,
    FailurePdf,
    Scheme,
    SimParams,
    decision_points,
)
from repro.core.simulator import (
    AttemptResult,
    SimResult,
    simulate,
    simulate_acc_attempt,
    simulate_attempt,
)

__all__ = [
    "HOUR",
    "ALL_SCHEMES",
    "REALISTIC_SCHEMES",
    "AppState",
    "Application",
    "AttemptResult",
    "Controller",
    "Event",
    "EventKind",
    "FailurePdf",
    "InstanceType",
    "Lifecycle",
    "Monitoring",
    "PriceTrace",
    "ProvisioningDecision",
    "SLA",
    "Scheme",
    "SimParams",
    "SimResult",
    "SpotEventGenerator",
    "Termination",
    "TraceModel",
    "Workflow",
    "algorithm1",
    "bill_run",
    "catalog",
    "constant_trace",
    "decision_points",
    "ensemble_seed",
    "expected_execution_time",
    "get_instance",
    "run_cost",
    "sample_traces_batch",
    "shift_trace",
    "simulate",
    "simulate_acc_attempt",
    "simulate_attempt",
    "spot_application",
    "step_trace",
    "synthetic_trace",
    "synthetic_traces_batch",
    "trace_ensemble",
]
