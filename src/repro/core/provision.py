"""Provisioning subsystem: Algorithm 1 (paper §VI-B).

Determines ``A_bid`` and ``instance_type`` for a job:

  1. retrieve S_info (catalog + price history),
  2. filter instance types meeting the SLA,
  3. A_bid = min on-demand cost over the feasible list (Eq. 7),
  4. pick the type minimizing Expected Execution Time (Eq. 8):

         EET_i = ( w * sum_{k>=w} f_i(k) + sum_{k<w} (k+r) f_i(k) )
                 / ( 1 - sum_{k<w} f_i(k) )

     with f_i the out-of-bid failure pdf from price history and r the
     recovery time.  Work ``w`` is expressed in pdf bins and scaled by the
     instance's relative compute throughput (ECU) so heterogeneous types are
     comparable.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.market import InstanceType, PriceTrace
from repro.core.schemes import FailurePdf


@dataclasses.dataclass(frozen=True)
class SLA:
    """Minimal service level: compute throughput and memory class."""

    min_compute_units: float = 0.0
    regions: tuple[str, ...] = ()  # empty = any
    os: str | None = None

    def admits(self, it: InstanceType) -> bool:
        if it.compute_units < self.min_compute_units:
            return False
        if self.regions and it.region not in self.regions:
            return False
        if self.os is not None and it.os != self.os:
            return False
        return True


def expected_execution_time(
    pdf: FailurePdf,
    work_s: float,
    recovery_s: float,
) -> float:
    """Eq. 8, in seconds.  ``pdf`` bins failure age; censored mass counts as
    surviving past ``work_s`` (success)."""
    w_bins = max(1, int(math.ceil(work_s / pdf.bin_s)))
    k = np.arange(len(pdf.pdf))
    fail_before = pdf.pdf[:w_bins] if w_bins <= len(pdf.pdf) else pdf.pdf
    p_fail = float(np.sum(fail_before))
    p_succeed = 1.0 - p_fail  # includes censored mass
    if p_succeed <= 0.0:
        return math.inf
    # expected wasted time per failed attempt: (k + r) f(k) summed over k < w
    wasted = float(np.sum((k[: len(fail_before)] * pdf.bin_s + recovery_s) * fail_before))
    # attempts are geometric; success attempt costs w
    return (work_s * p_succeed + wasted) / p_succeed


@dataclasses.dataclass(frozen=True)
class ProvisioningDecision:
    a_bid: float
    instance: InstanceType
    eet_s: float
    candidates: dict[str, float]  # instance name -> EET


def algorithm1(
    work_s: float,
    sla: SLA,
    catalog: list[InstanceType],
    histories: dict[str, PriceTrace],
    recovery_s: float = 300.0,
    reference_ecu: float = 8.0,
    pdf_cache: dict[tuple[str, float], FailurePdf] | None = None,
) -> ProvisioningDecision:
    """Paper Algorithm 1.  ``histories`` maps instance name -> price history.

    ``pdf_cache`` (keyed ``(name, round(bid, 6))``) lets repeated callers —
    the fleet controller re-provisions on every migration — skip rebuilding
    failure pdfs from the same history.
    """
    feasible = [it for it in catalog if sla.admits(it)]
    if not feasible:
        raise ValueError("no instance type meets the SLA")
    a_bid = min(it.on_demand for it in feasible)  # Eq. 7

    candidates: dict[str, float] = {}
    best: tuple[float, float, InstanceType] | None = None
    for it in feasible:
        hist = histories.get(it.name)
        if hist is None:
            continue
        if hist.next_available(a_bid, 0.0) is None:
            # Never below A_bid in recorded history: the empty failure pdf is
            # all censored mass, which Eq. 8 would misread as "never fails".
            eet = math.inf
        else:
            key = (it.name, round(a_bid, 6))
            pdf = pdf_cache.get(key) if pdf_cache is not None else None
            if pdf is None:
                pdf = FailurePdf.from_trace(hist, a_bid)
                if pdf_cache is not None:
                    pdf_cache[key] = pdf
            # scale work to this instance's speed
            w_scaled = work_s * (reference_ecu / it.compute_units)
            eet = expected_execution_time(pdf, w_scaled, recovery_s)
        candidates[it.name] = eet
        # ties (incl. the all-infeasible case) break towards cheaper on-demand
        if best is None or (eet, it.on_demand) < (best[0], best[1]):
            best = (eet, it.on_demand, it)
    if best is None:
        raise ValueError("no price history available for any feasible type")
    return ProvisioningDecision(a_bid=a_bid, instance=best[2], eet_s=best[0], candidates=candidates)
