"""Spot-market substrate: instance catalog and price traces.

The paper evaluates on the 64 Amazon EC2 spot instance types of 2011/2012
(8 hardware types x 4 regions x 2 OS) using the 3-month price history that
Amazon publishes for free.  Those historical traces are not redistributable,
so this module provides

  * an :class:`InstanceType` catalog matching the 2011 EC2 price sheet, and
  * a calibrated regime-switching trace generator whose marginal statistics
    (band around ~0.55-0.65x on-demand, occasional spikes above on-demand,
    price-change cadence of tens of minutes, $0.001 price grid) match the
    qualitative properties reported for the eu-west-1 m1.xlarge traces used
    in the paper and in Yi et al. [3].

Traces are piecewise-constant: ``prices[i]`` holds on ``[times[i], times[i+1])``.
Everything is deterministic given a seed.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

HOUR = 3600.0

# ---------------------------------------------------------------------------
# Instance catalog (2011 EC2 price sheet, us-east linux baseline; regional and
# OS multipliers reproduce the 64-type grid used by the paper / Yi et al.).
# ---------------------------------------------------------------------------

_BASE_TYPES = {
    # name: on-demand $/h (linux, us-east, 2011)
    "m1.small": 0.085,
    "m1.large": 0.34,
    "m1.xlarge": 0.68,
    "c1.medium": 0.17,
    "c1.xlarge": 0.68,
    "m2.xlarge": 0.50,
    "m2.2xlarge": 1.00,
    "m2.4xlarge": 2.00,
}

_REGIONS = {
    "us-east-1": 1.00,
    "us-west-1": 1.10,
    "eu-west-1": 1.10,
    "ap-southeast-1": 1.12,
}

_OS = {
    "linux": 1.00,
    "windows": 1.35,
}


@dataclasses.dataclass(frozen=True)
class InstanceType:
    """One (hardware, region, os) cell of the 64-type catalog."""

    name: str
    hardware: str
    region: str
    os: str
    on_demand: float  # $/h
    compute_units: float  # relative ECU throughput (scales job speed)

    @property
    def key(self) -> str:
        return f"{self.hardware}/{self.region}/{self.os}"


_ECU = {
    "m1.small": 1.0,
    "m1.large": 4.0,
    "m1.xlarge": 8.0,
    "c1.medium": 5.0,
    "c1.xlarge": 20.0,
    "m2.xlarge": 6.5,
    "m2.2xlarge": 13.0,
    "m2.4xlarge": 26.0,
}


def catalog() -> list[InstanceType]:
    """The 64 instance types used by the paper's evaluation."""
    out = []
    for hw, base in _BASE_TYPES.items():
        for region, rmul in _REGIONS.items():
            for os_name, omul in _OS.items():
                price = round(base * rmul * omul, 3)
                out.append(
                    InstanceType(
                        name=f"{hw}.{region}.{os_name}",
                        hardware=hw,
                        region=region,
                        os=os_name,
                        on_demand=price,
                        compute_units=_ECU[hw],
                    )
                )
    assert len(out) == 64
    return out


def get_instance(hardware: str, region: str = "eu-west-1", os_name: str = "linux") -> InstanceType:
    for it in catalog():
        if it.hardware == hardware and it.region == region and it.os == os_name:
            return it
    raise KeyError(f"{hardware}/{region}/{os_name}")


# ---------------------------------------------------------------------------
# Price traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PriceTrace:
    """Piecewise-constant spot-price trace.

    ``prices[i]`` holds on ``[times[i], times[i+1])``; ``times[0] == 0`` and
    ``times[-1]`` is the horizon.  After the horizon the last price holds
    (simulations must finish inside the horizon; the engine checks).
    """

    times: np.ndarray  # (N+1,) float64, strictly increasing
    prices: np.ndarray  # (N,) float64

    def __post_init__(self):
        assert self.times.ndim == 1 and self.prices.ndim == 1
        assert len(self.times) == len(self.prices) + 1
        assert self.times[0] == 0.0
        assert np.all(np.diff(self.times) > 0)

    @property
    def horizon(self) -> float:
        return float(self.times[-1])

    def segment_index(self, t: float) -> int:
        """Index of the segment containing time ``t``."""
        i = int(np.searchsorted(self.times, t, side="right")) - 1
        return min(max(i, 0), len(self.prices) - 1)

    def price_at(self, t: float) -> float:
        return float(self.prices[self.segment_index(t)])

    def next_change(self, t: float) -> float:
        """First segment boundary strictly after ``t`` (or horizon)."""
        i = int(np.searchsorted(self.times, t, side="right"))
        if i >= len(self.times):
            return self.horizon
        return float(self.times[i])

    def available_periods(self, bid: float) -> list[tuple[float, float]]:
        """Maximal intervals where ``price <= bid`` (instance can run)."""
        ok = self.prices <= bid
        periods: list[tuple[float, float]] = []
        start = None
        for i, flag in enumerate(ok):
            if flag and start is None:
                start = self.times[i]
            if not flag and start is not None:
                periods.append((float(start), float(self.times[i])))
                start = None
        if start is not None:
            periods.append((float(start), self.horizon))
        return periods

    def rising_edges(self) -> np.ndarray:
        """Times at which the price strictly increases."""
        idx = np.nonzero(np.diff(self.prices) > 0)[0] + 1
        return self.times[idx]


@dataclasses.dataclass(frozen=True)
class TraceModel:
    """Regime-switching generator calibrated to 2011 EC2 spot dynamics.

    Three regimes, matching the qualitative shape of the published m1.xlarge
    eu-west-1 history that the paper sweeps bids over:

      * *base*     — tight band just above the reserve floor (~0.53x on-demand);
                     the instance is available for any bid in the paper's sweep.
      * *elevated* — excursions a few percent above the base band, lasting tens
                     of minutes, a handful of times per day; these are the
                     out-of-bid events the schemes must survive.
      * *spike*    — rare jumps towards/above on-demand.

    Dwell times are exponential; prices land on the $0.001 grid the paper
    sweeps bids on.
    """

    base_center: float  # ~0.53 x on-demand (just below the paper's bid sweep)
    base_jitter: float  # +- jitter inside the base band
    elevated_low: float  # excursion band straddling the bid sweep
    elevated_high: float
    spike_low: float
    spike_high: float
    p_elevated: float = 0.18  # base -> elevated switch prob. per segment
    p_spike: float = 0.10  # elevated -> spike escalation prob.
    dwell_base_s: float = 3600.0
    dwell_elevated_s: float = 1800.0
    dwell_spike_s: float = 600.0
    grid: float = 0.001

    @staticmethod
    def for_instance(it: InstanceType) -> "TraceModel":
        od = it.on_demand
        return TraceModel(
            base_center=0.530 * od,
            base_jitter=0.008 * od,
            elevated_low=0.535 * od,
            elevated_high=0.60 * od,
            spike_low=0.75 * od,
            spike_high=2.5 * od,
        )

    def sample(self, horizon_s: float, seed: int) -> PriceTrace:
        rng = np.random.default_rng(seed)
        times = [0.0]
        prices: list[float] = []
        t = 0.0
        regime = "base"
        while t < horizon_s:
            if regime == "base":
                p = rng.normal(self.base_center, self.base_jitter)
                dwell = rng.exponential(self.dwell_base_s)
            elif regime == "elevated":
                p = rng.uniform(self.elevated_low, self.elevated_high)
                dwell = rng.exponential(self.dwell_elevated_s)
            else:  # spike
                p = rng.uniform(self.spike_low, self.spike_high)
                dwell = rng.exponential(self.dwell_spike_s)
            prices.append(max(self.grid, round(float(p) / self.grid) * self.grid))
            t += max(30.0, dwell)  # EC2 never updated faster than ~30 s
            times.append(min(t, horizon_s))
            u = rng.random()
            if regime == "base":
                regime = "elevated" if u < self.p_elevated else "base"
            elif regime == "elevated":
                if u < self.p_spike:
                    regime = "spike"
                elif u < 0.75:
                    regime = "base"
            else:
                regime = "base" if u < 0.7 else "elevated"
        return PriceTrace(times=np.asarray(times), prices=np.asarray(prices))


def synthetic_trace(
    instance: InstanceType,
    horizon_days: float = 30.0,
    seed: int = 0,
) -> PriceTrace:
    """Convenience: calibrated trace for one instance type."""
    model = TraceModel.for_instance(instance)
    return model.sample(horizon_days * 24 * HOUR, seed)


def trace_ensemble(
    instance: InstanceType,
    n: int = 8,
    horizon_days: float = 30.0,
    seed: int = 0,
) -> list[PriceTrace]:
    return [synthetic_trace(instance, horizon_days, seed * 1000 + i) for i in range(n)]


def shift_trace(trace: PriceTrace, offset_s: float) -> PriceTrace:
    """View of ``trace`` starting at ``offset_s`` (new t=0).  Lets ensembles
    sample job start times without regenerating traces."""
    if offset_s <= 0:
        return trace
    if offset_s >= trace.horizon:
        raise ValueError("offset beyond horizon")
    i = trace.segment_index(offset_s)
    times = np.concatenate([[0.0], trace.times[i + 1 :] - offset_s])
    prices = trace.prices[i:]
    return PriceTrace(times=times, prices=prices)


def constant_trace(price: float, horizon_s: float = 30 * 24 * HOUR) -> PriceTrace:
    return PriceTrace(times=np.asarray([0.0, horizon_s]), prices=np.asarray([price]))


def step_trace(segments: Sequence[tuple[float, float]], horizon_s: float | None = None) -> PriceTrace:
    """Build a trace from (start_time, price) pairs; for tests."""
    starts = [s for s, _ in segments]
    assert starts[0] == 0.0 and starts == sorted(starts)
    horizon = horizon_s if horizon_s is not None else starts[-1] + 30 * 24 * HOUR
    times = np.asarray(list(starts) + [horizon])
    prices = np.asarray([p for _, p in segments])
    return PriceTrace(times=times, prices=prices)
