"""Spot-market substrate: instance catalog and price traces.

The paper evaluates on the 64 Amazon EC2 spot instance types of 2011/2012
(8 hardware types x 4 regions x 2 OS) using the 3-month price history that
Amazon publishes for free.  Those historical traces are not redistributable,
so this module provides

  * an :class:`InstanceType` catalog matching the 2011 EC2 price sheet, and
  * a calibrated regime-switching trace generator whose marginal statistics
    (band around ~0.55-0.65x on-demand, occasional spikes above on-demand,
    price-change cadence of tens of minutes, $0.001 price grid) match the
    qualitative properties reported for the eu-west-1 m1.xlarge traces used
    in the paper and in Yi et al. [3].

Traces are piecewise-constant: ``prices[i]`` holds on ``[times[i], times[i+1])``.
Everything is deterministic given a seed.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Sequence

import numpy as np

HOUR = 3600.0

# ---------------------------------------------------------------------------
# Instance catalog (2011 EC2 price sheet, us-east linux baseline; regional and
# OS multipliers reproduce the 64-type grid used by the paper / Yi et al.).
# ---------------------------------------------------------------------------

_BASE_TYPES = {
    # name: on-demand $/h (linux, us-east, 2011)
    "m1.small": 0.085,
    "m1.large": 0.34,
    "m1.xlarge": 0.68,
    "c1.medium": 0.17,
    "c1.xlarge": 0.68,
    "m2.xlarge": 0.50,
    "m2.2xlarge": 1.00,
    "m2.4xlarge": 2.00,
}

_REGIONS = {
    "us-east-1": 1.00,
    "us-west-1": 1.10,
    "eu-west-1": 1.10,
    "ap-southeast-1": 1.12,
}

_OS = {
    "linux": 1.00,
    "windows": 1.35,
}


@dataclasses.dataclass(frozen=True)
class InstanceType:
    """One (hardware, region, os) cell of the 64-type catalog."""

    name: str
    hardware: str
    region: str
    os: str
    on_demand: float  # $/h
    compute_units: float  # relative ECU throughput (scales job speed)

    @property
    def key(self) -> str:
        return f"{self.hardware}/{self.region}/{self.os}"


_ECU = {
    "m1.small": 1.0,
    "m1.large": 4.0,
    "m1.xlarge": 8.0,
    "c1.medium": 5.0,
    "c1.xlarge": 20.0,
    "m2.xlarge": 6.5,
    "m2.2xlarge": 13.0,
    "m2.4xlarge": 26.0,
}


def catalog() -> list[InstanceType]:
    """The 64 instance types used by the paper's evaluation."""
    out = []
    for hw, base in _BASE_TYPES.items():
        for region, rmul in _REGIONS.items():
            for os_name, omul in _OS.items():
                price = round(base * rmul * omul, 3)
                out.append(
                    InstanceType(
                        name=f"{hw}.{region}.{os_name}",
                        hardware=hw,
                        region=region,
                        os=os_name,
                        on_demand=price,
                        compute_units=_ECU[hw],
                    )
                )
    assert len(out) == 64
    return out


def get_instance(hardware: str, region: str = "eu-west-1", os_name: str = "linux") -> InstanceType:
    for it in catalog():
        if it.hardware == hardware and it.region == region and it.os == os_name:
            return it
    raise KeyError(f"{hardware}/{region}/{os_name}")


# ---------------------------------------------------------------------------
# Price traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PriceTrace:
    """Piecewise-constant spot-price trace.

    ``prices[i]`` holds on ``[times[i], times[i+1])``; ``times[0] == 0`` and
    ``times[-1]`` is the horizon.  After the horizon the last price holds
    (simulations must finish inside the horizon; the engine checks).
    """

    times: np.ndarray  # (N+1,) float64, strictly increasing
    prices: np.ndarray  # (N,) float64

    def __post_init__(self):
        assert self.times.ndim == 1 and self.prices.ndim == 1
        assert len(self.times) == len(self.prices) + 1
        assert self.times[0] == 0.0
        assert np.all(np.diff(self.times) > 0)

    @property
    def horizon(self) -> float:
        return float(self.times[-1])

    def segment_index(self, t: float) -> int:
        """Index of the segment containing time ``t``."""
        i = int(np.searchsorted(self.times, t, side="right")) - 1
        return min(max(i, 0), len(self.prices) - 1)

    def price_at(self, t: float) -> float:
        return float(self.prices[self.segment_index(t)])

    def next_change(self, t: float) -> float:
        """First segment boundary strictly after ``t`` (or horizon)."""
        i = int(np.searchsorted(self.times, t, side="right"))
        if i >= len(self.times):
            return self.horizon
        return float(self.times[i])

    def available_periods(self, bid: float) -> list[tuple[float, float]]:
        """Maximal intervals where ``price <= bid`` (instance can run).

        Vectorized (``np.diff``/``np.nonzero`` over the segment mask): this is
        the hot path of every (scheme, bid) sweep and of fleet simulations.
        """
        ok = self.prices <= bid
        if not ok.any():
            return []
        edges = np.diff(ok.astype(np.int8))
        starts = np.nonzero(edges == 1)[0] + 1
        ends = np.nonzero(edges == -1)[0] + 1
        if ok[0]:
            starts = np.concatenate(([0], starts))
        if ok[-1]:
            ends = np.concatenate((ends, [len(self.prices)]))
        # times[len(prices)] is the horizon, so both cases read self.times.
        return [(float(self.times[s]), float(self.times[e])) for s, e in zip(starts, ends)]

    def next_available(self, bid: float, t: float) -> float | None:
        """Earliest time ``>= t`` with ``price <= bid`` (None if never again)."""
        if t >= self.horizon:
            return None
        i = self.segment_index(t)
        ok = self.prices <= bid
        if ok[i]:
            return t
        later = np.nonzero(ok[i + 1 :])[0]
        if len(later) == 0:
            return None
        return float(self.times[i + 1 + later[0]])

    def next_out_of_bid(self, bid: float, t: float) -> float:
        """End of the availability period containing ``t``: first boundary
        after ``t`` whose segment price exceeds ``bid`` (horizon if none)."""
        i = self.segment_index(t)
        bad = np.nonzero(self.prices[i + 1 :] > bid)[0]
        if len(bad) == 0:
            return self.horizon
        return float(self.times[i + 1 + bad[0]])

    def rising_edges(self) -> np.ndarray:
        """Times at which the price strictly increases."""
        idx = np.nonzero(np.diff(self.prices) > 0)[0] + 1
        return self.times[idx]


@dataclasses.dataclass(frozen=True)
class TraceModel:
    """Regime-switching generator calibrated to 2011 EC2 spot dynamics.

    Three regimes, matching the qualitative shape of the published m1.xlarge
    eu-west-1 history that the paper sweeps bids over:

      * *base*     — tight band just above the reserve floor (~0.53x on-demand);
                     the instance is available for any bid in the paper's sweep.
      * *elevated* — excursions a few percent above the base band, lasting tens
                     of minutes, a handful of times per day; these are the
                     out-of-bid events the schemes must survive.
      * *spike*    — rare jumps towards/above on-demand.

    Dwell times are exponential; prices land on the $0.001 grid the paper
    sweeps bids on.
    """

    base_center: float  # ~0.53 x on-demand (just below the paper's bid sweep)
    base_jitter: float  # +- jitter inside the base band
    elevated_low: float  # excursion band straddling the bid sweep
    elevated_high: float
    spike_low: float
    spike_high: float
    p_elevated: float = 0.18  # base -> elevated switch prob. per segment
    p_spike: float = 0.10  # elevated -> spike escalation prob.
    dwell_base_s: float = 3600.0
    dwell_elevated_s: float = 1800.0
    dwell_spike_s: float = 600.0
    grid: float = 0.001

    @staticmethod
    def for_instance(it: InstanceType) -> "TraceModel":
        od = it.on_demand
        return TraceModel(
            base_center=0.530 * od,
            base_jitter=0.008 * od,
            elevated_low=0.535 * od,
            elevated_high=0.60 * od,
            spike_low=0.75 * od,
            spike_high=2.5 * od,
        )

    def sample(self, horizon_s: float, seed: int) -> PriceTrace:
        rng = np.random.default_rng(seed)
        times = [0.0]
        prices: list[float] = []
        t = 0.0
        regime = "base"
        while t < horizon_s:
            if regime == "base":
                p = rng.normal(self.base_center, self.base_jitter)
                dwell = rng.exponential(self.dwell_base_s)
            elif regime == "elevated":
                p = rng.uniform(self.elevated_low, self.elevated_high)
                dwell = rng.exponential(self.dwell_elevated_s)
            else:  # spike
                p = rng.uniform(self.spike_low, self.spike_high)
                dwell = rng.exponential(self.dwell_spike_s)
            prices.append(max(self.grid, round(float(p) / self.grid) * self.grid))
            t += max(30.0, dwell)  # EC2 never updated faster than ~30 s
            times.append(min(t, horizon_s))
            u = rng.random()
            if regime == "base":
                regime = "elevated" if u < self.p_elevated else "base"
            elif regime == "elevated":
                if u < self.p_spike:
                    regime = "spike"
                elif u < 0.75:
                    regime = "base"
            else:
                regime = "base" if u < 0.7 else "elevated"
        return PriceTrace(times=np.asarray(times), prices=np.asarray(prices))


def sample_traces_batch(
    models: Sequence[TraceModel],
    horizon_s: float,
    seeds: Sequence[int],
) -> list[PriceTrace]:
    """NumPy-batched trace generation: one trace per (model, seed) pair.

    The regime-switching Markov chain is advanced once per segment for the
    whole batch (a few thousand vector steps) instead of once per segment per
    trace in Python, so generating the full 64-type x many-seed grid of a
    fleet sweep takes tens of milliseconds rather than seconds.

    Each entry draws from its own ``default_rng(seed)`` stream, so a trace is
    deterministic in ``(model, horizon_s, seed)`` regardless of what else is
    in the batch.  The stream call *order* differs from :meth:`TraceModel.sample`
    (bulk array draws vs per-segment draws), so batched traces are
    statistically identical but not bitwise equal to scalar ones.
    """
    if len(models) != len(seeds):
        raise ValueError("models and seeds must have equal length")
    n = len(models)
    if n == 0:
        return []
    # Expected segment dwell is ~3100 s under the stationary regime mix;
    # 2x headroom makes running out of pre-drawn segments astronomically rare
    # (scalar fallback below covers it).
    k_max = max(64, int(horizon_s / 1500.0))

    u = np.empty((n, k_max))  # regime-transition uniforms
    z = np.empty((n, k_max))  # base-band normals
    e = np.empty((n, k_max))  # dwell exponentials
    v = np.empty((n, k_max))  # elevated/spike uniforms
    for b, seed in enumerate(seeds):
        rng = np.random.default_rng(seed)
        u[b] = rng.random(k_max)
        z[b] = rng.standard_normal(k_max)
        e[b] = rng.exponential(1.0, k_max)
        v[b] = rng.random(k_max)

    def col(attr: str) -> np.ndarray:
        return np.asarray([getattr(m, attr) for m in models])[:, None]

    p_elevated, p_spike = col("p_elevated"), col("p_spike")
    regimes = np.empty((n, k_max), dtype=np.int8)  # 0 base, 1 elevated, 2 spike
    regime = np.zeros(n, dtype=np.int8)
    pe, ps = p_elevated[:, 0], p_spike[:, 0]
    for k in range(k_max):
        regimes[:, k] = regime
        uk = u[:, k]
        from_base = np.where(uk < pe, 1, 0)
        from_elev = np.where(uk < ps, 2, np.where(uk < 0.75, 0, 1))
        from_spike = np.where(uk < 0.7, 0, 1)
        regime = np.select(
            [regime == 0, regime == 1], [from_base, from_elev], default=from_spike
        ).astype(np.int8)

    is_base, is_elev, is_spike = regimes == 0, regimes == 1, regimes == 2
    price_base = col("base_center") + col("base_jitter") * z
    price_elev = col("elevated_low") + (col("elevated_high") - col("elevated_low")) * v
    price_spike = col("spike_low") + (col("spike_high") - col("spike_low")) * v
    prices = np.select([is_base, is_elev, is_spike], [price_base, price_elev, price_spike])
    grid = col("grid")
    prices = np.maximum(grid, np.round(prices / grid) * grid)

    dwell_scale = np.select(
        [is_base, is_elev, is_spike],
        [col("dwell_base_s"), col("dwell_elevated_s"), col("dwell_spike_s")],
    )
    dwell = np.maximum(30.0, e * dwell_scale)
    cum = np.cumsum(dwell, axis=1)

    out: list[PriceTrace] = []
    for b in range(n):
        if cum[b, -1] < horizon_s:  # ran out of pre-drawn segments
            out.append(models[b].sample(horizon_s, seeds[b]))
            continue
        n_seg = int(np.searchsorted(cum[b], horizon_s)) + 1
        times = np.concatenate(([0.0], cum[b, :n_seg]))
        times[-1] = min(times[-1], horizon_s)
        out.append(PriceTrace(times=times, prices=prices[b, :n_seg].copy()))
    return out


def synthetic_trace(
    instance: InstanceType,
    horizon_days: float = 30.0,
    seed: int = 0,
) -> PriceTrace:
    """Convenience: calibrated trace for one instance type."""
    model = TraceModel.for_instance(instance)
    return model.sample(horizon_days * 24 * HOUR, seed)


def ensemble_seed(instance: InstanceType, base_seed: int = 0, i: int = 0) -> int:
    """Decorrelated per-instance seed.

    ``trace_ensemble(it, seed=s)`` uses raw seeds ``s*1000 + i`` for every
    instance type, so two *different* types sampled with the same base seed
    share an rng stream: their model parameters all scale linearly with the
    on-demand price, making the traces near-proportional — a price spike then
    hits every type simultaneously and silently defeats fleet
    diversification.  Mixing the instance name into the seed restores
    independence while staying deterministic.
    """
    if base_seed < 0:
        raise ValueError("base_seed must be non-negative")
    h = zlib.crc32(instance.name.encode())
    return ((base_seed * 1000 + i) << 32) | h


def synthetic_traces_batch(
    instances: Sequence[InstanceType],
    horizon_days: float = 30.0,
    base_seed: int = 0,
    n_seeds: int = 1,
) -> dict[str, list[PriceTrace]]:
    """Batched, decorrelated traces for a set of instance types.

    Returns ``{instance.name: [trace_for_seed_0, ..., trace_for_seed_{n-1}]}``
    generated in one :func:`sample_traces_batch` call with
    :func:`ensemble_seed` streams.
    """
    models = []
    seeds = []
    for it in instances:
        m = TraceModel.for_instance(it)
        for i in range(n_seeds):
            models.append(m)
            seeds.append(ensemble_seed(it, base_seed, i))
    traces = sample_traces_batch(models, horizon_days * 24 * HOUR, seeds)
    out: dict[str, list[PriceTrace]] = {}
    for j, it in enumerate(instances):
        out[it.name] = traces[j * n_seeds : (j + 1) * n_seeds]
    return out


def trace_ensemble(
    instance: InstanceType,
    n: int = 8,
    horizon_days: float = 30.0,
    seed: int = 0,
) -> list[PriceTrace]:
    return [synthetic_trace(instance, horizon_days, seed * 1000 + i) for i in range(n)]


def shift_trace(trace: PriceTrace, offset_s: float) -> PriceTrace:
    """View of ``trace`` starting at ``offset_s`` (new t=0).  Lets ensembles
    sample job start times without regenerating traces."""
    if offset_s <= 0:
        return trace
    if offset_s >= trace.horizon:
        raise ValueError("offset beyond horizon")
    i = trace.segment_index(offset_s)
    times = np.concatenate([[0.0], trace.times[i + 1 :] - offset_s])
    prices = trace.prices[i:]
    return PriceTrace(times=times, prices=prices)


def constant_trace(price: float, horizon_s: float = 30 * 24 * HOUR) -> PriceTrace:
    return PriceTrace(times=np.asarray([0.0, horizon_s]), prices=np.asarray([price]))


def step_trace(segments: Sequence[tuple[float, float]], horizon_s: float | None = None) -> PriceTrace:
    """Build a trace from (start_time, price) pairs; for tests."""
    starts = [s for s, _ in segments]
    assert starts[0] == 0.0 and starts == sorted(starts)
    horizon = horizon_s if horizon_s is not None else starts[-1] + 30 * 24 * HOUR
    times = np.asarray(list(starts) + [horizon])
    prices = np.asarray([p for _, p in segments])
    return PriceTrace(times=times, prices=prices)
