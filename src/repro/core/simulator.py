"""Discrete-event simulator for checkpointing schemes on spot instances.

Re-implements the (corrected) simulator of the paper's §VII: work progresses
at unit rate while an instance is up and not writing a checkpoint; billing
follows :mod:`repro.core.billing` (hour-start prices, free partial hour only
on out-of-bid kills); each scheme of :mod:`repro.core.schemes` schedules
checkpoint windows and — for ACC — self-terminations.

The engine is event-driven over the piecewise-constant price trace, so a
30-day trace with thousands of price changes simulates in well under a
millisecond per (scheme, bid).
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import billing
from repro.core.billing import Termination
from repro.core.market import PriceTrace
from repro.core.schemes import (
    FailurePdf,
    Scheme,
    SimParams,
    adapt_should_checkpoint,
    decision_points,
)

_EPS = 1e-9


@dataclasses.dataclass
class InstanceRun:
    launch: float
    end: float
    termination: Termination
    cost: float


@dataclasses.dataclass
class SimResult:
    scheme: Scheme
    bid: float
    work_s: float
    completed: bool
    completion_time: float  # wall-clock seconds from t=0 to job completion
    cost: float  # $
    n_checkpoints: int
    n_kills: int  # provider (out-of-bid) terminations
    n_self_terminations: int  # ACC user terminations
    work_lost_s: float
    runs: list[InstanceRun]

    @property
    def cost_time_product(self) -> float:
        return self.cost * self.completion_time

    @property
    def availability_overhead(self) -> float:
        """completion_time / work_s — 1.0 is perfect."""
        return self.completion_time / self.work_s


def simulate(
    trace: PriceTrace,
    scheme: Scheme,
    work_s: float,
    bid: float,
    params: SimParams | None = None,
    failure_pdf: FailurePdf | None = None,
    initial_saved_work: float = 0.0,
) -> SimResult:
    """Simulate one job of ``work_s`` seconds under ``scheme`` with ``bid``.

    For ACC, ``bid`` is the *application* bid A_bid (the instance bid S_bid is
    taken as infinite).  For ADAPT, ``failure_pdf`` defaults to the pdf
    estimated from this trace's own history (the paper estimates it from the
    published 3-month history).

    ``initial_saved_work`` resumes a job mid-trace from an existing
    checkpoint: the first launch restores that much completed work (the job
    finishes once total work reaches ``work_s``).  This is how the fleet
    migration engine re-homes a killed job on a new instance type; the
    default of 0.0 keeps single-job behavior identical.
    """
    params = params or SimParams()
    if not 0.0 <= initial_saved_work <= work_s:
        raise ValueError(f"initial_saved_work {initial_saved_work} outside [0, {work_s}]")
    if scheme == Scheme.ACC:
        return _simulate_acc(trace, work_s, bid, params, initial_saved_work)
    if scheme == Scheme.ADAPT and failure_pdf is None:
        failure_pdf = FailurePdf.from_trace(trace, bid)
    return _simulate_bid_limited(trace, scheme, work_s, bid, params, failure_pdf, initial_saved_work)


# ---------------------------------------------------------------------------
# Bid-limited schemes: NONE / OPT / HOUR / EDGE / ADAPT
# ---------------------------------------------------------------------------


def _simulate_bid_limited(
    trace: PriceTrace,
    scheme: Scheme,
    work_s: float,
    bid: float,
    params: SimParams,
    failure_pdf: FailurePdf | None,
    initial_saved_work: float = 0.0,
) -> SimResult:
    saved = initial_saved_work
    n_ckpt = 0
    n_kills = 0
    work_lost = 0.0
    runs: list[InstanceRun] = []

    for a, b in trace.available_periods(bid):
        killed = b < trace.horizon  # period truncated by out-of-bid
        start_work = a + params.t_r
        if scheme == Scheme.NONE:
            saved = 0.0 if runs else saved  # NONE restarts from scratch after a kill

        if start_work >= b:
            # killed before recovery finished: pay (partial hour free), no progress
            if killed:
                cost = billing.run_cost(trace, a, b, Termination.OUT_OF_BID, params.billing_period_s)
                runs.append(InstanceRun(a, b, Termination.OUT_OF_BID, cost))
                n_kills += 1
            continue

        done_at, work_end, saved, took = _run_period(
            trace, scheme, a, start_work, b, saved, work_s, params, failure_pdf
        )
        n_ckpt += took

        if done_at is not None:
            cost = billing.run_cost(trace, a, done_at, Termination.USER, params.billing_period_s)
            runs.append(InstanceRun(a, done_at, Termination.USER, cost))
            return _result(scheme, bid, work_s, True, done_at, runs, n_ckpt, n_kills, 0, work_lost)

        # out-of-bid kill at b
        cost = billing.run_cost(trace, a, b, Termination.OUT_OF_BID, params.billing_period_s)
        runs.append(InstanceRun(a, b, Termination.OUT_OF_BID, cost))
        n_kills += 1
        work_lost += work_end - (0.0 if scheme == Scheme.NONE else saved)

    return _result(scheme, bid, work_s, False, math.inf, runs, n_ckpt, n_kills, 0, work_lost)


def _run_period(trace, scheme, launch, start_work, b, saved, work_s, params, failure_pdf):
    """Walk one availability period. Returns (done_at|None, work_at_end, saved, n_ckpt)."""
    t = start_work
    work = saved
    n_ckpt = 0

    # Precompute scheduled checkpoint-window starts for stateless schemes.
    if scheme == Scheme.HOUR:
        starts = []
        k = 1
        while True:
            s = launch + k * params.billing_period_s - params.t_c
            if s >= b:
                break
            if s > start_work:
                starts.append(s)
            k += 1
    elif scheme == Scheme.EDGE:
        starts = [float(e) for e in trace.rising_edges() if start_work < e < b]
    elif scheme == Scheme.OPT:
        # Oracle: only checkpoint if the kill (at b) arrives before completion.
        remaining = work_s - work
        completes_at = start_work + remaining
        if completes_at <= b + _EPS:
            starts = []
        else:
            s = b - params.t_c
            starts = [s] if s > start_work else []
    elif scheme in (Scheme.NONE,):
        starts = []
    else:  # ADAPT: dynamic decisions, handled below
        starts = None

    if starts is not None:
        for s in starts:
            # work segment [t, s)
            if work + (s - t) >= work_s - _EPS:
                return t + (work_s - work), work_s, saved, n_ckpt
            work += s - t
            if s + params.t_c <= b + _EPS:  # checkpoint completes in-period
                saved = work
                n_ckpt += 1
            t = s + params.t_c
            if t >= b:
                return None, work, saved, n_ckpt
        if work + (b - t) >= work_s - _EPS:
            return t + (work_s - work), work_s, saved, n_ckpt
        return None, work + (b - t), saved, n_ckpt

    # ADAPT: decide every adapt_interval_s whether to checkpoint now.
    next_decision = start_work + params.adapt_interval_s
    while True:
        seg_end = min(next_decision, b)
        if work + (seg_end - t) >= work_s - _EPS:
            return t + (work_s - work), work_s, saved, n_ckpt
        work += seg_end - t
        t = seg_end
        if t >= b:
            return None, work, saved, n_ckpt
        age = t - launch
        if adapt_should_checkpoint(failure_pdf, age, work - saved, params):
            if t + params.t_c <= b + _EPS:
                saved = work
                n_ckpt += 1
            t = min(t + params.t_c, b)
            if t >= b:
                return None, work, saved, n_ckpt
        next_decision = t + params.adapt_interval_s


# ---------------------------------------------------------------------------
# Single-attempt primitive (fleet migration engine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttemptResult:
    """Outcome of one instance attempt (a single availability period, or —
    for ACC — a single lease between launch and self-termination).

    All times are absolute on the given trace.  ``work_done_s`` and
    ``saved_work_s`` include ``initial_saved_work``; on a kill only
    ``saved_work_s`` survives to the next attempt.  ``self_terminated`` marks
    an ACC user termination at an hour boundary — like ``killed`` it ends the
    attempt with the job unfinished, so a fleet controller treats either as a
    migration trigger, but it is billed as a USER termination (full final
    hour) per the paper's corrected billing.
    """

    launch: float
    end: float  # completion instant, kill instant, or horizon
    completed: bool
    killed: bool  # provider out-of-bid kill at ``end`` (False at horizon)
    cost: float
    work_done_s: float
    saved_work_s: float
    n_checkpoints: int
    self_terminated: bool = False  # ACC only

    def termination(self) -> Termination:
        if self.completed or self.self_terminated:
            return Termination.USER
        return Termination.OUT_OF_BID


def simulate_attempt(
    trace: PriceTrace,
    scheme: Scheme,
    work_s: float,
    bid: float,
    start_t: float = 0.0,
    params: SimParams | None = None,
    failure_pdf: FailurePdf | None = None,
    initial_saved_work: float = 0.0,
) -> AttemptResult | None:
    """Run a *single* instance attempt: launch at the first availability at or
    after ``start_t`` and walk one availability period to completion, kill, or
    horizon.

    Unlike :func:`simulate`, which relaunches on the *same* trace after every
    kill, this returns control to the caller at the first kill so a fleet
    controller can re-provision onto a different instance type (migration).
    Returns ``None`` when the trace is never available again under ``bid``.
    ACC is bid-unlimited (the instance is never provider-killed), so fleet
    attempts use the bid-limited schemes.
    """
    params = params or SimParams()
    if scheme == Scheme.ACC:
        raise ValueError("simulate_attempt supports bid-limited schemes; use simulate() for ACC")
    if not 0.0 <= initial_saved_work <= work_s:
        raise ValueError(f"initial_saved_work {initial_saved_work} outside [0, {work_s}]")
    if scheme == Scheme.ADAPT and failure_pdf is None:
        failure_pdf = FailurePdf.from_trace(trace, bid)

    launch = trace.next_available(bid, start_t)
    if launch is None or launch >= trace.horizon:
        return None
    b = trace.next_out_of_bid(bid, launch)
    killed = b < trace.horizon
    saved = initial_saved_work

    start_work = launch + params.t_r
    if start_work >= b:
        # killed (or horizon) before recovery finished: no progress
        cost = billing.run_cost(trace, launch, b, Termination.OUT_OF_BID, params.billing_period_s)
        return AttemptResult(launch, b, False, killed, cost, saved, saved, 0)

    done_at, work_end, saved, took = _run_period(
        trace, scheme, launch, start_work, b, saved, work_s, params, failure_pdf
    )
    if done_at is not None:
        cost = billing.run_cost(trace, launch, done_at, Termination.USER, params.billing_period_s)
        return AttemptResult(launch, done_at, True, False, cost, work_s, saved, took)
    cost = billing.run_cost(trace, launch, b, Termination.OUT_OF_BID, params.billing_period_s)
    return AttemptResult(launch, b, False, killed, cost, work_end, saved, took)


def simulate_acc_attempt(
    trace: PriceTrace,
    work_s: float,
    a_bid: float,
    start_t: float = 0.0,
    params: SimParams | None = None,
    initial_saved_work: float = 0.0,
) -> AttemptResult | None:
    """Run a *single* ACC lease: launch at the first admissible instant at or
    after ``start_t`` and walk hour boundaries to completion, self-termination
    (``self_terminated=True``), or the horizon.

    The ACC analogue of :func:`simulate_attempt`: ACC instances are never
    provider-killed (S_bid ~ infinity), but a self-termination ends the lease
    with the job unfinished exactly like an out-of-bid kill does for the
    bid-limited schemes — so a fleet controller can re-provision the job onto
    a different type from its last checkpoint.  Launch timing mirrors
    :func:`simulate`'s ACC loop: immediate at ``start_t == 0`` when the price
    already admits ``a_bid``, otherwise the next admissible poll tick; chain
    attempts with ``start_t = previous.end + eps`` to reproduce the multi-
    lease ``simulate`` outcome exactly (including the final lease, which is
    billed OUT_OF_BID-style when it runs off the horizon).  Returns ``None``
    when no admissible launch exists before the horizon.
    """
    params = params or SimParams()
    if not 0.0 <= initial_saved_work <= work_s:
        raise ValueError(f"initial_saved_work {initial_saved_work} outside [0, {work_s}]")

    if start_t == 0.0 and trace.price_at(0.0) <= a_bid:
        launch = 0.0
    else:
        launch = _next_launch_time(trace, start_t, a_bid, params.poll_s)
    if launch is None or launch >= trace.horizon:
        return None

    done_at, terminated_at, work, saved, n_ckpt = _acc_lease(
        trace, launch, work_s, a_bid, initial_saved_work, params
    )
    if done_at is not None:
        cost = billing.run_cost(trace, launch, done_at, Termination.USER, params.billing_period_s)
        return AttemptResult(launch, done_at, True, False, cost, work_s, saved, n_ckpt)
    if terminated_at is None:  # ran off the horizon: billed OUT_OF_BID
        # (full hours charged, partial final hour free), mirroring simulate()
        cost = billing.run_cost(
            trace, launch, trace.horizon, Termination.OUT_OF_BID, params.billing_period_s
        )
        return AttemptResult(launch, trace.horizon, False, False, cost, work, saved, n_ckpt)
    cost = billing.run_cost(trace, launch, terminated_at, Termination.USER, params.billing_period_s)
    return AttemptResult(
        launch, terminated_at, False, False, cost, work, saved, n_ckpt, self_terminated=True
    )


# ---------------------------------------------------------------------------
# ACC (paper §VI)
# ---------------------------------------------------------------------------


def _next_launch_time(trace: PriceTrace, t_from: float, a_bid: float, poll_s: float) -> float | None:
    """First poll tick >= t_from with price <= A_bid (paper: user-defined poll)."""
    t = math.ceil(t_from / poll_s - _EPS) * poll_s
    while t < trace.horizon:
        if trace.price_at(t) <= a_bid:
            return t
        # jump to the next of (next poll tick, next price change) — price is
        # piecewise constant so polls inside one segment all agree.
        nxt_change = trace.next_change(t)
        t = max(t + poll_s, math.ceil(nxt_change / poll_s - _EPS) * poll_s)
    return None


def _acc_lease(
    trace: PriceTrace,
    launch: float,
    work_s: float,
    a_bid: float,
    saved: float,
    params: SimParams,
) -> tuple[float | None, float | None, float, float, int]:
    """Walk one ACC lease from ``launch``: hour-by-hour checkpoint/terminate
    decisions at the Eq. (3)-(4) decision points until completion,
    self-termination, or the horizon.

    Returns ``(done_at, terminated_at, work, saved, n_ckpt)``; exactly one of
    ``done_at`` / ``terminated_at`` is set unless the lease runs off the
    horizon (both ``None``).  Shared by :func:`simulate` (ACC) and the fleet
    primitive :func:`simulate_acc_attempt` so the two can never drift.
    """
    L = launch
    t = L + params.t_r
    work = saved
    k = 1
    n_ckpt = 0
    done_at = None
    terminated_at = None
    while True:
        t_h = L + k * params.billing_period_s
        t_cd, t_td = decision_points(t_h, params)
        if t_h > trace.horizon:
            break
        take_ckpt = trace.price_at(t_cd) > a_bid
        seg_end = (t_h - params.t_c) if take_ckpt else t_h
        if seg_end > t:
            if work + (seg_end - t) >= work_s - _EPS:
                done_at = t + (work_s - work)
                break
            work += seg_end - t
        t = seg_end
        if take_ckpt:
            saved = work  # snapshot at window start, completes exactly at t_h
            n_ckpt += 1
            t = t_h
        if trace.price_at(t_td) > a_bid:
            terminated_at = t_h
            break
        k += 1
    return done_at, terminated_at, work, saved, n_ckpt


def _simulate_acc(
    trace: PriceTrace,
    work_s: float,
    a_bid: float,
    params: SimParams,
    initial_saved_work: float = 0.0,
) -> SimResult:
    saved = initial_saved_work
    n_ckpt = 0
    n_term = 0
    work_lost = 0.0
    runs: list[InstanceRun] = []

    t0 = 0.0 if trace.price_at(0.0) <= a_bid else None
    launch_at = t0 if t0 is not None else _next_launch_time(trace, 0.0, a_bid, params.poll_s)

    while launch_at is not None and launch_at < trace.horizon:
        L = launch_at
        done_at, terminated_at, work, saved, ckpts = _acc_lease(
            trace, L, work_s, a_bid, saved, params
        )
        n_ckpt += ckpts

        if done_at is not None:
            cost = billing.run_cost(trace, L, done_at, Termination.USER, params.billing_period_s)
            runs.append(InstanceRun(L, done_at, Termination.USER, cost))
            return _result(Scheme.ACC, a_bid, work_s, True, done_at, runs, n_ckpt, 0, n_term, work_lost)

        if terminated_at is None:  # ran off the horizon: bill like the
            # bid-limited schemes bill a horizon-truncated period (full hours
            # charged, partial final hour free) so cross-scheme cost
            # comparisons at non-completing bids aren't biased towards ACC
            if trace.horizon > L:
                cost = billing.run_cost(
                    trace, L, trace.horizon, Termination.OUT_OF_BID, params.billing_period_s
                )
                runs.append(InstanceRun(L, trace.horizon, Termination.OUT_OF_BID, cost))
            break

        cost = billing.run_cost(trace, L, terminated_at, Termination.USER, params.billing_period_s)
        runs.append(InstanceRun(L, terminated_at, Termination.USER, cost))
        n_term += 1
        work_lost += work - saved
        launch_at = _next_launch_time(trace, terminated_at + _EPS, a_bid, params.poll_s)

    return _result(Scheme.ACC, a_bid, work_s, False, math.inf, runs, n_ckpt, 0, n_term, work_lost)


def _result(scheme, bid, work_s, completed, done_at, runs, n_ckpt, n_kills, n_term, work_lost) -> SimResult:
    return SimResult(
        scheme=scheme,
        bid=bid,
        work_s=work_s,
        completed=completed,
        completion_time=done_at,
        cost=sum(r.cost for r in runs),
        n_checkpoints=n_ckpt,
        n_kills=n_kills,
        n_self_terminations=n_term,
        work_lost_s=work_lost,
        runs=runs,
    )


# Bid sweeps (paper §VII) live on the engine surface: build a
# `repro.engine.Scenario` and call `repro.engine.run` — the deprecated
# `sweep_bids` shim is gone (see docs/engine.md for the migration table;
# `EngineResult.to_sweep_dict` still produces the legacy result shape).
