"""Model zoo: the 10 assigned architectures as config-driven pure-JAX models.

Families: dense GQA transformers, MoE (expert-parallel), Mamba-1 SSM,
RG-LRU/local-attention hybrid, Whisper-style enc-dec, and a VLM backbone with
a stubbed vision frontend.  All parameters are plain pytrees paired with a
logical-axes pytree for sharding (see repro.parallel.sharding).
"""

from repro.models.config import ModelConfig
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_axes,
    prefill,
)

__all__ = [
    "ModelConfig",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "param_axes",
    "prefill",
]
