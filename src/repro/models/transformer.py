"""Config-driven assembly of all architecture families.

Layers are stored as a *list* of per-layer param dicts and applied in a
Python-unrolled loop.  This is deliberate (DESIGN.md §Analysis): XLA's
``cost_analysis`` counts a ``while``/``scan`` body once regardless of trip
count, so unrolled layers keep the dry-run roofline accounting exact; XLA's
buffer liveness makes unrolled execution memory-equivalent to scan, and
``jax.checkpoint`` per layer provides the remat policy.

Public API: init_params / param_axes / forward / loss_fn / init_cache /
prefill / decode_step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import moe_ep as MEP
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.params import ParamBuilder
from repro.parallel import shard

# ---------------------------------------------------------------------------
# Layer kinds per family
# ---------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> list[str]:
    if cfg.family == "ssm":
        return ["mamba"] * cfg.n_layers
    if cfg.family == "hybrid":
        pattern = cfg.block_pattern or ("rec",)
        return [pattern[i % len(pattern)] for i in range(cfg.n_layers)]
    if cfg.family == "moe":
        return ["moe"] * cfg.n_layers
    if cfg.family == "encdec":
        return ["decoder"] * cfg.n_layers
    return ["dense"] * cfg.n_layers  # dense | vlm


def _init_layer(cfg: ModelConfig, kind: str, key) -> tuple[dict, dict]:
    b = ParamBuilder(key, dtype=jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    if kind == "mamba":
        L.init_norm(b, "norm", cfg)
        S.init_mamba(b, "mixer", cfg)
    elif kind == "rec":
        L.init_norm(b, "norm1", cfg)
        R.init_rglru_block(b, "mixer", cfg)
        L.init_norm(b, "norm2", cfg)
        L.init_mlp(b, "mlp", cfg)
    elif kind in ("dense", "attn"):
        L.init_norm(b, "norm1", cfg)
        L.init_attention(b, "attn", cfg)
        L.init_norm(b, "norm2", cfg)
        L.init_mlp(b, "mlp", cfg)
    elif kind == "moe":
        L.init_norm(b, "norm1", cfg)
        L.init_attention(b, "attn", cfg)
        L.init_norm(b, "norm2", cfg)
        M.init_moe(b, "moe", cfg)
        if cfg.dense_residual:
            L.init_mlp(b, "mlp", cfg)
    elif kind == "encoder":
        L.init_norm(b, "norm1", cfg)
        L.init_attention(b, "attn", cfg)
        L.init_norm(b, "norm2", cfg)
        L.init_mlp(b, "mlp", cfg)
    elif kind == "decoder":
        L.init_norm(b, "norm1", cfg)
        L.init_attention(b, "self_attn", cfg)
        L.init_norm(b, "norm_cross", cfg)
        L.init_attention(b, "cross_attn", cfg)
        L.init_norm(b, "norm2", cfg)
        L.init_mlp(b, "mlp", cfg)
    else:
        raise ValueError(kind)
    return b.build()


def init_params(cfg: ModelConfig, key) -> dict:
    return _init(cfg, key)[0]


def param_axes(cfg: ModelConfig) -> dict:
    """Logical-axes pytree matching init_params; no allocation (eval_shape)."""
    holder = {}

    def probe(key):
        p, a = _init(cfg, key)
        holder["axes"] = a
        return p

    jax.eval_shape(probe, jax.random.PRNGKey(0))
    return holder["axes"]


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the parameters (for dry-run lowering)."""
    return jax.eval_shape(lambda k: _init(cfg, k)[0], jax.random.PRNGKey(0))


def _init(cfg: ModelConfig, key):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    keys = jax.random.split(key, cfg.n_layers + 3)
    eb = ParamBuilder(keys[0], dtype=dtype)
    L.init_embedding(eb, cfg)
    L.init_norm(eb, "final_norm", cfg)
    params, axes = eb.build()
    kinds = layer_kinds(cfg)
    params["layers"], axes["layers"] = [], []
    for i, kind in enumerate(kinds):
        p, a = _init_layer(cfg, kind, keys[i + 1])
        params["layers"].append(p)
        axes["layers"].append(a)
    if cfg.family == "encdec":
        params["encoder"], axes["encoder"] = [], []
        enc_keys = jax.random.split(keys[-1], cfg.encoder_layers)
        for i in range(cfg.encoder_layers):
            p, a = _init_layer(cfg, "encoder", enc_keys[i])
            params["encoder"].append(p)
            axes["encoder"].append(a)
        nb = ParamBuilder(keys[-2], dtype=dtype)
        L.init_norm(nb, "encoder_norm", cfg)
        p, a = nb.build()
        params.update(p)
        axes.update(a)
    return params, axes


# ---------------------------------------------------------------------------
# Blocks (full-sequence)
# ---------------------------------------------------------------------------


def _moe(cfg: ModelConfig, p, h):
    if cfg.moe_impl == "ep":
        return MEP.apply_moe_ep(cfg, p, "moe", h)
    return M.apply_moe(cfg, p, "moe", h)


def _apply_layer(cfg: ModelConfig, kind: str, p, x, *, memory=None, q_block, kv_block):
    """One layer, full sequence.  ``memory``: encoder output for decoders."""
    if kind == "mamba":
        h, _ = S.apply_mamba(cfg, p, "mixer", L.apply_norm(cfg, p, "norm", x))
        return x + h
    if kind == "rec":
        x = x + R.apply_rglru_block(cfg, p, "mixer", L.apply_norm(cfg, p, "norm1", x))
        return x + L.apply_mlp(cfg, p, "mlp", L.apply_norm(cfg, p, "norm2", x))
    if kind in ("dense", "attn"):
        window = cfg.window if (cfg.family == "hybrid" and kind == "attn") else 0
        a, _ = L.apply_attention(
            cfg, p, "attn", L.apply_norm(cfg, p, "norm1", x), causal=True, window=window,
            q_block=q_block, kv_block=kv_block,
        )
        x = x + a
        return x + L.apply_mlp(cfg, p, "mlp", L.apply_norm(cfg, p, "norm2", x))
    if kind == "moe":
        a, _ = L.apply_attention(
            cfg, p, "attn", L.apply_norm(cfg, p, "norm1", x), causal=True, q_block=q_block, kv_block=kv_block
        )
        x = x + a
        h = L.apply_norm(cfg, p, "norm2", x)
        y, aux = _moe(cfg, p, h)
        if cfg.dense_residual:
            y = y + L.apply_mlp(cfg, p, "mlp", h)
        return x + y, aux
    if kind == "encoder":
        a, _ = L.apply_attention(
            cfg, p, "attn", L.apply_norm(cfg, p, "norm1", x), causal=False, q_block=q_block, kv_block=kv_block
        )
        x = x + a
        return x + L.apply_mlp(cfg, p, "mlp", L.apply_norm(cfg, p, "norm2", x))
    if kind == "decoder":
        a, _ = L.apply_attention(
            cfg, p, "self_attn", L.apply_norm(cfg, p, "norm1", x), causal=True, q_block=q_block, kv_block=kv_block
        )
        x = x + a
        c = _cross_attention(cfg, p, "cross_attn", L.apply_norm(cfg, p, "norm_cross", x), memory)
        x = x + c
        return x + L.apply_mlp(cfg, p, "mlp", L.apply_norm(cfg, p, "norm2", x))
    raise ValueError(kind)


def _cross_attention(cfg: ModelConfig, p, name: str, x, memory):
    """Dense cross-attention (memory is short — whisper: 1500 frames)."""
    from repro.kernels.flash_attention.ref import naive_attention

    q = jnp.einsum("bsd,dhe->bshe", x, p[f"{name}.wq"])
    k = jnp.einsum("bsd,dke->bske", memory, p[f"{name}.wk"])
    v = jnp.einsum("bsd,dke->bske", memory, p[f"{name}.wv"])
    o = naive_attention(q, k, v, causal=False)
    return jnp.einsum("bshe,hed->bsd", o, p[f"{name}.wo"])


def _embed_inputs(cfg: ModelConfig, params, batch):
    x = L.embed_tokens(cfg, params, batch["tokens"])
    if cfg.family == "vlm" and "vision_embeds" in batch:
        # stubbed frontend: splice precomputed patch embeddings over the
        # positions flagged by vision_mask (assignment: backbone only)
        ve = batch["vision_embeds"].astype(x.dtype)  # (B, Tv, d)
        mask = batch["vision_mask"]  # (B, S) bool, exactly Tv true per row
        # positions of vision tokens: cumsum index into ve
        idx = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1
        idx = jnp.clip(idx, 0, ve.shape[1] - 1)
        spliced = jnp.take_along_axis(ve, idx[..., None], axis=1)
        x = jnp.where(mask[..., None], spliced, x)
    return x


def _encode(cfg: ModelConfig, params, frames, *, q_block, kv_block):
    x = frames.astype(params["embed.tokens"].dtype)
    if cfg.learned_pos:
        pos = jnp.arange(x.shape[1])
        x = x + jnp.take(params["embed.positions"], pos, axis=0)[None]
    for p in params["encoder"]:
        x = _apply_layer(cfg, "encoder", p, x, q_block=q_block, kv_block=kv_block)
    return L.apply_norm(cfg, params, "encoder_norm", x)


def forward(cfg: ModelConfig, params, batch, *, q_block: int = 1024, kv_block: int = 1024, remat: bool = False):
    """Full forward.  Returns (logits, aux)."""
    x = _embed_inputs(cfg, params, batch)
    memory = None
    if cfg.family == "encdec":
        memory = _encode(cfg, params, batch["frames"], q_block=q_block, kv_block=kv_block)
    aux = {"load_balance_loss": jnp.zeros((), jnp.float32), "drop_frac": jnp.zeros((), jnp.float32)}
    kinds = layer_kinds(cfg)
    n_moe = max(1, sum(k == "moe" for k in kinds))

    def run_layer(kind, p, x):
        return _apply_layer(cfg, kind, p, x, memory=memory, q_block=q_block, kv_block=kv_block)

    for kind, p in zip(kinds, params["layers"]):
        fn = jax.checkpoint(functools.partial(run_layer, kind)) if remat else functools.partial(run_layer, kind)
        out = fn(p, x)
        if kind == "moe":
            x, layer_aux = out
            aux["load_balance_loss"] += layer_aux["load_balance_loss"] / n_moe
            aux["drop_frac"] += layer_aux["drop_frac"] / n_moe
        else:
            x = out
    x = L.apply_norm(cfg, params, "final_norm", x)
    logits = L.unembed(cfg, params, x)
    return logits, aux


def loss_fn(cfg: ModelConfig, params, batch, **fw_kwargs):
    """Next-token cross-entropy (+ MoE aux).  labels: -100 = ignore."""
    logits, aux = forward(cfg, params, batch, **fw_kwargs)
    labels = batch["labels"]
    valid = labels >= 0
    labels_c = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    label_logit = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0].astype(jnp.float32)
    nll = (lse - label_logit) * valid.astype(jnp.float32)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
    if cfg.family == "moe":
        loss = loss + cfg.router_aux_weight * aux["load_balance_loss"]
    metrics = {
        "loss": loss,
        "nll": jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1),
        "aux": aux,
    }
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _attn_cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    if cfg.family == "hybrid" and kind == "attn" and cfg.window:
        return min(max_len, cfg.window)  # rolling window cache
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    caches = []
    for kind in layer_kinds(cfg):
        if kind == "mamba":
            caches.append(S.init_mamba_cache(cfg, batch, dtype))
        elif kind == "rec":
            caches.append(R.init_rglru_cache(cfg, batch, dtype))
        elif kind == "decoder":
            caches.append(
                {
                    "self": L.init_attention_cache(cfg, batch, max_len, dtype),
                    "cross_k": jnp.zeros((batch, cfg.encoder_positions, cfg.n_kv_heads, cfg.d_head), dtype),
                    "cross_v": jnp.zeros((batch, cfg.encoder_positions, cfg.n_kv_heads, cfg.d_head), dtype),
                }
            )
        else:
            caches.append(L.init_attention_cache(cfg, batch, _attn_cache_len(cfg, kind, max_len), dtype))
    return {"layers": caches, "len": jnp.zeros((), jnp.int32)}


def cache_axes(cfg: ModelConfig) -> dict:
    caches = []
    for kind in layer_kinds(cfg):
        if kind == "mamba":
            caches.append(S.mamba_cache_axes())
        elif kind == "rec":
            caches.append(R.rglru_cache_axes())
        elif kind == "decoder":
            caches.append(
                {
                    "self": L.attention_cache_axes(),
                    "cross_k": ("batch", None, "kv_heads", "head_dim"),
                    "cross_v": ("batch", None, "kv_heads", "head_dim"),
                }
            )
        else:
            caches.append(L.attention_cache_axes())
    return {"layers": caches, "len": ()}


def prefill(cfg: ModelConfig, params, batch, max_len: int, *, q_block: int = 1024, kv_block: int = 1024):
    """Run the prompt, fill the cache, return (last_logits, cache).

    For simplicity the prompt length S is taken as dense (no padding); the
    cache is written at positions [0, S).
    """
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    cache = init_cache(cfg, bsz, max_len, dtype)
    x = _embed_inputs(cfg, params, batch)
    memory = None
    if cfg.family == "encdec":
        memory = _encode(cfg, params, batch["frames"], q_block=q_block, kv_block=kv_block)
    kinds = layer_kinds(cfg)
    new_caches = []
    for kind, p, lc in zip(kinds, params["layers"], cache["layers"]):
        if kind == "mamba":
            h_in = L.apply_norm(cfg, p, "norm", x)
            xz = jnp.einsum("bsd,de->bse", h_in, p["mixer.in_proj"])
            x_in, z = jnp.split(xz, 2, axis=-1)
            x_conv, _ = S._causal_conv(x_in, p["mixer.conv_w"], p["mixer.conv_b"])
            x_act = jax.nn.silu(x_conv)
            dtA, dBx, cmat = S._ssm_inputs(cfg, p, "mixer", x_act)
            from repro.kernels.ssm_scan import ops as ssm_ops

            y, h_last = ssm_ops.ssm_scan(dtA, dBx, cmat)
            y = y + p["mixer.D"][None, None, :] * x_act.astype(jnp.float32)
            y = y.astype(x.dtype) * jax.nn.silu(z)
            out = jnp.einsum("bse,ed->bsd", y, p["mixer.out_proj"])
            x = x + out
            new_caches.append({"conv": S.conv_tail(x_in, cfg.ssm_conv).astype(dtype), "h": h_last})
        elif kind == "rec":
            h_in = L.apply_norm(cfg, p, "norm1", x)
            xb = jnp.einsum("bsd,dw->bsw", h_in, p["mixer.in_x"])
            gate = jnp.einsum("bsd,dw->bsw", h_in, p["mixer.in_gate"])
            x_conv, _ = S._causal_conv(xb, p["mixer.conv_w"], p["mixer.conv_b"])
            x_act = jax.nn.silu(x_conv)
            log_a, i_g = R._gates(cfg, p, "mixer", x_act)
            from repro.kernels.rglru_scan import ops as rglru_ops

            h, h_last = rglru_ops.rglru_scan(log_a, i_g * x_act.astype(jnp.float32))
            y = h.astype(x.dtype) * jax.nn.silu(gate)
            out = jnp.einsum("bsw,wd->bsd", y, p["mixer.out_proj"])
            x = x + out
            x = x + L.apply_mlp(cfg, p, "mlp", L.apply_norm(cfg, p, "norm2", x))
            new_caches.append({"conv": S.conv_tail(xb, cfg.ssm_conv).astype(dtype), "h": h_last})
        elif kind == "decoder":
            h_in = L.apply_norm(cfg, p, "norm1", x)
            a, (k, v) = L.apply_attention(cfg, p, "self_attn", h_in, causal=True, q_block=q_block, kv_block=kv_block)
            x = x + a
            ck = jnp.einsum("bsd,dke->bske", memory, p["cross_attn.wk"])
            cv = jnp.einsum("bsd,dke->bske", memory, p["cross_attn.wv"])
            x = x + _cross_attention(cfg, p, "cross_attn", L.apply_norm(cfg, p, "norm_cross", x), memory)
            x = x + L.apply_mlp(cfg, p, "mlp", L.apply_norm(cfg, p, "norm2", x))
            sc = lc["self"]
            sc = {
                "k": jax.lax.dynamic_update_slice_in_dim(sc["k"], k.astype(dtype), 0, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(sc["v"], v.astype(dtype), 0, axis=1),
                "len": jnp.asarray(s, jnp.int32),
            }
            new_caches.append({"self": sc, "cross_k": ck.astype(dtype), "cross_v": cv.astype(dtype)})
        else:
            window = cfg.window if (cfg.family == "hybrid" and kind == "attn") else 0
            h_in = L.apply_norm(cfg, p, "norm1", x)
            name = "attn"
            a, (k, v) = L.apply_attention(
                cfg, p, name, h_in, causal=True, window=window, q_block=q_block, kv_block=kv_block
            )
            x = x + a
            if kind == "moe":
                h = L.apply_norm(cfg, p, "norm2", x)
                y, _ = _moe(cfg, p, h)
                if cfg.dense_residual:
                    y = y + L.apply_mlp(cfg, p, "mlp", h)
                x = x + y
            else:
                x = x + L.apply_mlp(cfg, p, "mlp", L.apply_norm(cfg, p, "norm2", x))
            clen = lc["k"].shape[1]
            if clen < s:
                # rolling window cache: slot layout must match decode's
                # circular indexing (position p at slot p % clen)
                k_w = jnp.roll(k[:, -clen:], s % clen, axis=1)
                v_w = jnp.roll(v[:, -clen:], s % clen, axis=1)
                new_caches.append(
                    {"k": k_w.astype(dtype), "v": v_w.astype(dtype), "len": jnp.asarray(s, jnp.int32)}
                )
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(lc["k"], k.astype(dtype), 0, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(lc["v"], v.astype(dtype), 0, axis=1)
                new_caches.append({"k": kc, "v": vc, "len": jnp.asarray(s, jnp.int32)})
    x = L.apply_norm(cfg, params, "final_norm", x)
    logits = L.unembed(cfg, params, x[:, -1:])
    return logits, {"layers": new_caches, "len": jnp.asarray(s, jnp.int32)}


def decode_step(cfg: ModelConfig, params, tokens, cache):
    """One decode step.  tokens: (B, 1) -> (logits (B,1,V), new cache)."""
    pos = cache["len"]
    x = L.embed_tokens(cfg, params, tokens, position_offset=pos)
    kinds = layer_kinds(cfg)
    new_caches = []
    for kind, p, lc in zip(kinds, params["layers"], cache["layers"]):
        if kind == "mamba":
            h, nc = S.apply_mamba_decode(cfg, p, "mixer", L.apply_norm(cfg, p, "norm", x), lc)
            x = x + h
            new_caches.append(nc)
        elif kind == "rec":
            h, nc = R.apply_rglru_decode(cfg, p, "mixer", L.apply_norm(cfg, p, "norm1", x), lc)
            x = x + h
            x = x + L.apply_mlp(cfg, p, "mlp", L.apply_norm(cfg, p, "norm2", x))
            new_caches.append(nc)
        elif kind == "decoder":
            a, sc = L.apply_attention_decode(cfg, p, "self_attn", L.apply_norm(cfg, p, "norm1", x), lc["self"])
            x = x + a
            x = x + _cross_decode(cfg, p, "cross_attn", L.apply_norm(cfg, p, "norm_cross", x), lc)
            x = x + L.apply_mlp(cfg, p, "mlp", L.apply_norm(cfg, p, "norm2", x))
            new_caches.append({"self": sc, "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]})
        elif kind == "moe":
            a, nc = L.apply_attention_decode(cfg, p, "attn", L.apply_norm(cfg, p, "norm1", x), lc)
            x = x + a
            h = L.apply_norm(cfg, p, "norm2", x)
            y, _ = _moe(cfg, p, h)
            if cfg.dense_residual:
                y = y + L.apply_mlp(cfg, p, "mlp", h)
            x = x + y
            new_caches.append(nc)
        else:
            window = cfg.window if (cfg.family == "hybrid" and kind == "attn") else 0
            a, nc = L.apply_attention_decode(
                cfg, p, "attn", L.apply_norm(cfg, p, "norm1", x), lc, window=window
            )
            x = x + a
            x = x + L.apply_mlp(cfg, p, "mlp", L.apply_norm(cfg, p, "norm2", x))
            new_caches.append(nc)
    x = L.apply_norm(cfg, params, "final_norm", x)
    logits = L.unembed(cfg, params, x)
    return logits, {"layers": new_caches, "len": pos + 1}


def _cross_decode(cfg: ModelConfig, p, name: str, x, lc):
    from repro.kernels.flash_attention.ref import naive_attention

    q = jnp.einsum("bsd,dhe->bshe", x, p[f"{name}.wq"])
    o = naive_attention(q, lc["cross_k"], lc["cross_v"], causal=False)
    return jnp.einsum("bshe,hed->bsd", o, p[f"{name}.wo"])
