"""RecurrentGemma recurrent block: conv + RG-LRU gated linear recurrence.

Block layout follows Griffin: linear x/gate branches, short causal conv on
the x branch, RG-LRU recurrence, gated output projection.  The rnn width is
tensor-parallel ("rnn" logical axis -> "model").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan import ops as rglru_ops
from repro.kernels.rglru_scan.ref import RG_LRU_C
from repro.models.config import ModelConfig
from repro.models.params import ParamBuilder
from repro.models.ssm import _causal_conv
from repro.parallel import shard


def init_rglru_block(b: ParamBuilder, name: str, cfg: ModelConfig):
    d, w, kc = cfg.d_model, cfg.rnn_width, cfg.ssm_conv
    b.dense(f"{name}.in_x", (d, w), ("fsdp", "rnn"))
    b.dense(f"{name}.in_gate", (d, w), ("fsdp", "rnn"))
    b.dense(f"{name}.conv_w", (kc, w), ("conv", "rnn"), scale=0.5)
    b.zeros(f"{name}.conv_b", (w,), ("rnn",))
    b.dense(f"{name}.w_a", (w, w), ("rnn", None), scale=0.02)
    b.dense(f"{name}.w_i", (w, w), ("rnn", None), scale=0.02)
    # Lambda init so that a^c in (0.9, 0.999) at r=1 (Griffin appendix)
    b.const(f"{name}.Lambda", jnp.full((w,), 0.7, jnp.float32), ("rnn",))
    b.dense(f"{name}.out_proj", (w, d), ("rnn", "fsdp"))


def _gates(cfg: ModelConfig, params, name: str, x_act):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x_act, params[f"{name}.w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x_act, params[f"{name}.w_i"]).astype(jnp.float32))
    lam = jax.nn.softplus(params[f"{name}.Lambda"].astype(jnp.float32))
    log_a = -RG_LRU_C * lam[None, None, :] * r
    return log_a, i


def apply_rglru_block(cfg: ModelConfig, params, name: str, x):
    xb = jnp.einsum("bsd,dw->bsw", x, params[f"{name}.in_x"])
    gate = jnp.einsum("bsd,dw->bsw", x, params[f"{name}.in_gate"])
    xb = shard(xb, "batch", "seq", "rnn")
    x_conv, _ = _causal_conv(xb, params[f"{name}.conv_w"], params[f"{name}.conv_b"])
    x_act = jax.nn.silu(x_conv)
    log_a, i = _gates(cfg, params, name, x_act)
    h, _ = rglru_ops.rglru_scan(log_a, i * x_act.astype(jnp.float32))
    y = h.astype(x.dtype) * jax.nn.silu(gate)
    y = shard(y, "batch", "seq", "rnn")
    out = jnp.einsum("bsw,wd->bsd", y, params[f"{name}.out_proj"])
    return shard(out, "batch", "seq", "embed")


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    w, kc = cfg.rnn_width, cfg.ssm_conv
    return {
        "conv": jnp.zeros((batch, kc - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_cache_axes():
    return {"conv": ("batch", "conv", "rnn"), "h": ("batch", "rnn")}


def apply_rglru_decode(cfg: ModelConfig, params, name: str, x, cache):
    xb = jnp.einsum("bsd,dw->bsw", x, params[f"{name}.in_x"])
    gate = jnp.einsum("bsd,dw->bsw", x, params[f"{name}.in_gate"])
    x_conv, conv_state = _causal_conv(xb, params[f"{name}.conv_w"], params[f"{name}.conv_b"], cache["conv"])
    x_act = jax.nn.silu(x_conv)  # (B,1,W)
    log_a, i = _gates(cfg, params, name, x_act)
    h, _ = rglru_ops.rglru_step(log_a[:, 0], (i * x_act.astype(jnp.float32))[:, 0], cache["h"])
    y = h[:, None, :].astype(x.dtype) * jax.nn.silu(gate)
    out = jnp.einsum("bsw,wd->bsd", y, params[f"{name}.out_proj"])
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "h": h}
