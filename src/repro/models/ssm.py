"""Mamba-1 block (falcon-mamba-7b): conv + selective state-space scan.

Inner width D = expand * d_model is tensor-parallel ("mlp" logical axis) —
the scan is elementwise over D so TP requires no collectives inside the
block (TPU adaptation note in DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import ops as ssm_ops
from repro.models.config import ModelConfig
from repro.models.params import ParamBuilder
from repro.parallel import shard


def init_mamba(b: ParamBuilder, name: str, cfg: ModelConfig):
    d, di, n, r, kc = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    b.dense(f"{name}.in_proj", (d, 2 * di), ("fsdp", "mlp"))
    b.dense(f"{name}.conv_w", (kc, di), ("conv", "mlp"), scale=0.5)
    b.zeros(f"{name}.conv_b", (di,), ("mlp",))
    b.dense(f"{name}.x_proj", (di, r + 2 * n), ("mlp", None))
    b.dense(f"{name}.dt_proj", (r, di), (None, "mlp"))
    b.zeros(f"{name}.dt_bias", (di,), ("mlp",))
    # A_log init: log of 1..N per channel (S4D-real init)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    b.const(f"{name}.A_log", jnp.log(a), ("mlp", "state"))
    b.ones(f"{name}.D", (di,), ("mlp",), dtype=jnp.float32)
    b.dense(f"{name}.out_proj", (di, d), ("mlp", "fsdp"))


def conv_tail(x, k: int):
    """Last k-1 positions of x (B,S,D), left-padded with zeros if S < k-1 —
    the decode conv state after a prefill of any length."""
    b, s, d = x.shape
    if s >= k - 1:
        return x[:, s - (k - 1) :]
    pad = jnp.zeros((b, k - 1 - s, d), x.dtype)
    return jnp.concatenate([pad, x], axis=1)


def _causal_conv(x, w, bias, state=None):
    """Depthwise causal conv over time.  x: (B,S,D); w: (K,D).

    ``state``: optional (B, K-1, D) left context (decode); returns new state.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, D)
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1) :] if k > 1 else None
    return out + bias[None, None, :], new_state


def _ssm_inputs(cfg: ModelConfig, params, name: str, x_act):
    """x_act: (B, S, D) -> (dtA, dBx, C) for the scan."""
    n, r = cfg.ssm_state, cfg.dt_rank
    proj = jnp.einsum("bsd,de->bse", x_act, params[f"{name}.x_proj"])
    dt_low, b_c = proj[..., :r], proj[..., r:]
    bmat, cmat = b_c[..., :n], b_c[..., n:]  # (B,S,N)
    dt = jnp.einsum("bsr,rd->bsd", dt_low, params[f"{name}.dt_proj"]) + params[f"{name}.dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))  # (B,S,D)
    a = -jnp.exp(params[f"{name}.A_log"].astype(jnp.float32))  # (D,N)
    dtA = dt[..., None] * a[None, None]  # (B,S,D,N) log-decay (<=0)
    dBx = (dt * x_act.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[:, :, None, :]
    return dtA, dBx, cmat


def apply_mamba(cfg: ModelConfig, params, name: str, x):
    """Full-sequence mamba block.  x: (B,S,d) -> (out, final_state)."""
    xz = jnp.einsum("bsd,de->bse", x, params[f"{name}.in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = shard(x_in, "batch", "seq", "mlp")
    x_conv, _ = _causal_conv(x_in, params[f"{name}.conv_w"], params[f"{name}.conv_b"])
    x_act = jax.nn.silu(x_conv)
    dtA, dBx, cmat = _ssm_inputs(cfg, params, name, x_act)
    y, h_last = ssm_ops.ssm_scan(dtA, dBx, cmat)
    y = y + params[f"{name}.D"][None, None, :] * x_act.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    y = shard(y, "batch", "seq", "mlp")
    out = jnp.einsum("bse,ed->bsd", y, params[f"{name}.out_proj"])
    return shard(out, "batch", "seq", "embed"), h_last


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, n, kc = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": jnp.zeros((batch, kc - 1, di), dtype),
        "h": jnp.zeros((batch, di, n), jnp.float32),
    }


def mamba_cache_axes():
    return {"conv": ("batch", "conv", "mlp"), "h": ("batch", "mlp", "state")}


def apply_mamba_decode(cfg: ModelConfig, params, name: str, x, cache):
    """Single-token step.  x: (B,1,d); cache: {conv:(B,K-1,D), h:(B,D,N)}."""
    xz = jnp.einsum("bsd,de->bse", x, params[f"{name}.in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_state = _causal_conv(x_in, params[f"{name}.conv_w"], params[f"{name}.conv_b"], cache["conv"])
    x_act = jax.nn.silu(x_conv)  # (B,1,D)
    dtA, dBx, cmat = _ssm_inputs(cfg, params, name, x_act)
    y, h = ssm_ops.ssm_step(dtA[:, 0], dBx[:, 0], cmat[:, 0], cache["h"])
    y = y + params[f"{name}.D"][None, :] * x_act[:, 0].astype(jnp.float32)
    y = y[:, None, :].astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params[f"{name}.out_proj"])
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "h": h}
