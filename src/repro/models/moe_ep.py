"""Expert-parallel MoE via shard_map (hillclimb: collective-optimal dispatch).

The annotation-based dispatch in moe.py scatters tokens into a globally
(batch, experts, capacity, d) buffer and lets the SPMD partitioner pick the
collectives; measured on kimi-k2 train_4k it picks catastrophically
(~1.6e14 wire bytes/device/step — §Perf).  This module expresses the same
math with *explicit* locality:

  * activations are replicated along "model" (they already are: batch is
    data-sharded, d unsharded), so routing is computed redundantly per rank
    — zero communication;
  * each model rank gathers ONLY the tokens routed to its E/tp local
    experts (local gather), runs its expert FFNs, scatters results into a
    local (B, S, d) buffer;
  * one psum over "model" combines expert outputs — the same wire cost as
    a dense TP FFN's all-reduce.

Per layer the collective traffic drops from O(B*E*C*d) to O(B*S*d).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.moe import moe_capacity
from repro.parallel.sharding import active_abstract_mesh, compat_shard_map, current_rules


def _mesh_for_ep():
    mesh = active_abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return None
    return mesh


def apply_moe_ep(cfg: ModelConfig, params, name: str, x):
    """Drop-in replacement for moe.apply_moe; falls back to it off-mesh."""
    mesh = _mesh_for_ep()
    if mesh is None:
        from repro.models.moe import apply_moe

        return apply_moe(cfg, params, name, x)

    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    tp = sizes["model"]
    e, k = cfg.n_experts, cfg.top_k
    if e % tp != 0:
        from repro.models.moe import apply_moe

        return apply_moe(cfg, params, name, x)
    e_loc = e // tp
    bsz, s, d = x.shape
    c = moe_capacity(cfg, s)
    tk = s * k

    batch_axes = tuple(a for a in ("pod", "data") if a in sizes and bsz % sizes[a] == 0)
    # batch divisibility across the full product
    prod = 1
    kept = []
    for a in batch_axes:
        if bsz % (prod * sizes[a]) == 0:
            kept.append(a)
            prod *= sizes[a]
    bspec = tuple(kept) if len(kept) > 1 else (kept[0] if kept else None)

    wi_up = params[f"{name}.wi_up"]
    wo = params[f"{name}.wo"]
    router = params[f"{name}.router"]
    wi_gate = params[f"{name}.wi_gate"] if cfg.gated_mlp else None

    def shard_fn(x_blk, router_w, wi_up_l, wo_l, *maybe_gate):
        wi_gate_l = maybe_gate[0] if maybe_gate else None
        b_loc = x_blk.shape[0]
        rank = jax.lax.axis_index("model")
        logits = jnp.einsum("bsd,de->bse", x_blk, router_w).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)  # identical on every model rank
        top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

        eid = top_e.reshape(b_loc, tk)
        owned = (eid // e_loc) == rank
        local_e = jnp.where(owned, eid % e_loc, e_loc)  # e_loc = overflow bucket
        sort_idx = jnp.argsort(local_e, axis=1, stable=True)
        sorted_e = jnp.take_along_axis(local_e, sort_idx, axis=1)
        counts = jnp.zeros((b_loc, e_loc + 1), jnp.int32).at[
            jnp.arange(b_loc)[:, None], local_e
        ].add(1)
        offsets = jnp.cumsum(counts, axis=1) - counts
        pos = jnp.arange(tk)[None, :] - jnp.take_along_axis(offsets, sorted_e, axis=1)
        keep = (sorted_e < e_loc) & (pos < c)
        pos = jnp.minimum(pos, c - 1)
        slot_e = jnp.minimum(sorted_e, e_loc - 1)

        brange = jnp.arange(b_loc)[:, None]
        tok = sort_idx // k
        gathered = x_blk[brange, tok] * keep[..., None].astype(x_blk.dtype)
        buf = jnp.zeros((b_loc, e_loc, c, d), x_blk.dtype).at[brange, slot_e, pos].add(gathered)

        up = jnp.einsum("becd,edf->becf", buf, wi_up_l)
        if wi_gate_l is not None:
            h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wi_gate_l)) * up
        else:
            h = jax.nn.gelu(up)
        out_buf = jnp.einsum("becf,efd->becd", h, wo_l)

        back = out_buf[brange, slot_e, pos] * keep[..., None].astype(x_blk.dtype)
        w_sorted = jnp.take_along_axis(top_w.reshape(b_loc, tk), sort_idx, axis=1)
        back = back * w_sorted[..., None].astype(x_blk.dtype)
        y = jnp.zeros((b_loc, s, d), x_blk.dtype).at[brange, tok].add(back)
        y = jax.lax.psum(y, "model")

        # aux (replicated along model; mean over the data axes)
        frac_tokens = jnp.zeros((b_loc, e), jnp.float32).at[brange, eid].add(1.0) / tk
        lb = e * jnp.mean(jnp.sum(frac_tokens * jnp.mean(probs, axis=1), axis=-1))
        kept_n = jax.lax.psum(jnp.sum(keep.astype(jnp.float32)), "model")
        drop = 1.0 - kept_n / (b_loc * tk)
        if kept:
            lb = jax.lax.pmean(lb, tuple(kept))
            drop = jax.lax.pmean(drop, tuple(kept))
        return y, lb, drop

    in_specs = [
        P(bspec, None, None),  # x: replicated along model
        P(None, None),  # router
        P("model", None, None),  # expert weights: E sharded
        P("model", None, None),
    ]
    args = [x, router, wi_up, wo]
    if wi_gate is not None:
        in_specs.append(P("model", None, None))
        args.append(wi_gate)
    out_specs = (P(bspec, None, None), P(), P())
    y, lb, drop = compat_shard_map(
        shard_fn, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs
    )(*args)
    return y, {"load_balance_loss": lb, "drop_frac": drop}
