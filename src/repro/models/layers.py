"""Shared layer library: norms, RoPE, GQA attention, MLPs, embeddings.

Everything is a pure function over (config, params, activations); parameter
construction lives beside each apply function so init and apply stay in sync.
Logical sharding annotations use repro.parallel.shard (no-ops off-mesh).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as attn_ops
from repro.models.config import ModelConfig
from repro.models.params import ParamBuilder
from repro.parallel import shard
from repro.parallel.sharding import active_abstract_mesh

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(b: ParamBuilder, name: str, cfg: ModelConfig, width: int | None = None):
    d = width or cfg.d_model
    if cfg.norm == "rmsnorm":
        b.ones(f"{name}.scale", (d,), ("embed",))
    else:
        b.ones(f"{name}.scale", (d,), ("embed",))
        b.zeros(f"{name}.bias", (d,), ("embed",))


def apply_norm(cfg: ModelConfig, params, name: str, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6)
        return (y * params[f"{name}.scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y * params[f"{name}.scale"].astype(jnp.float32) + params[f"{name}.bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(cfg: ModelConfig, positions):
    """positions: (...,) int32 -> cos/sin of shape (..., d_head//2)."""
    d = cfg.d_head
    inv_freq = 1.0 / (cfg.rope_theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:  # (S, D/2) -> broadcast over batch and heads
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:  # (B, S, D/2)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window, optional RoPE / learned positions)
# ---------------------------------------------------------------------------


def init_attention(b: ParamBuilder, name: str, cfg: ModelConfig):
    # "fsdp" on the non-TP dim: ZeRO-3 sharding over (pod, data); XLA inserts
    # the all-gather-on-use / reduce-scatter-on-grad pattern from the sharding
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    b.dense(f"{name}.wq", (d, h, dh), ("fsdp", "heads", "head_dim"))
    b.dense(f"{name}.wk", (d, kv, dh), ("fsdp", "kv_heads", "head_dim"))
    b.dense(f"{name}.wv", (d, kv, dh), ("fsdp", "kv_heads", "head_dim"))
    b.dense(f"{name}.wo", (h, dh, d), ("heads", "head_dim", "fsdp"))


def _qkv(cfg: ModelConfig, params, name: str, x, positions=None):
    q = jnp.einsum("bsd,dhe->bshe", x, params[f"{name}.wq"])
    k = jnp.einsum("bsd,dke->bske", x, params[f"{name}.wk"])
    v = jnp.einsum("bsd,dke->bske", x, params[f"{name}.wv"])
    if not cfg.learned_pos and positions is not None:
        cos, sin = rope_frequencies(cfg, positions)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def apply_attention(
    cfg: ModelConfig,
    params,
    name: str,
    x,
    *,
    causal=True,
    window=0,
    q_block=1024,
    kv_block=1024,
):
    """Full-sequence (train/prefill) attention.  Returns (out, (k, v))."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _qkv(cfg, params, name, x, positions)
    o = attn_ops.flash_attention(
        q, k, v, causal=causal, window=window, q_block=q_block, kv_block=kv_block
    )
    out = jnp.einsum("bshe,hed->bsd", o, params[f"{name}.wo"])
    return shard(out, "batch", "seq", "embed"), (k, v)


def apply_attention_decode(cfg: ModelConfig, params, name: str, x, cache, *, window=0):
    """One-token decode.  cache: dict(k=(B,S_c,KV,D), v=..., len=scalar int32).

    If the cache is window-sized (S_c <= window), it is treated as a
    *circular* buffer: the new token writes at ``len % S_c`` and every slot
    holds one of the most recent S_c positions — RoPE keys carry absolute
    positions, so attention scores stay correct after wrap-around.
    """
    b, one, _ = x.shape
    pos = cache["len"]  # scalar int32: current length before append
    s_c = cache["k"].shape[1]
    circular = bool(window) and s_c <= window
    q = jnp.einsum("bsd,dhe->bshe", x, params[f"{name}.wq"])
    k_new = jnp.einsum("bsd,dke->bske", x, params[f"{name}.wk"])
    v_new = jnp.einsum("bsd,dke->bske", x, params[f"{name}.wv"])
    if not cfg.learned_pos:
        cos, sin = rope_frequencies(cfg, pos[None])
        q = apply_rope(q, cos[None], sin[None])
        k_new = apply_rope(k_new, cos[None], sin[None])
    # SP path: sequence-sharded cache + distributed flash-decoding merge
    from repro.parallel.sharding import current_rules
    from repro.parallel import sp_decode

    if (
        not circular
        and current_rules().get("kv_seq") == "model"
        and sp_decode.sp_available(s_c)
    ):
        mesh = active_abstract_mesh()
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        data_prod = 1
        for a in ("pod", "data"):
            data_prod *= sizes.get(a, 1)
        o, k_cache, v_cache = sp_decode.sp_decode_attention_update(
            q, k_new, v_new, cache["k"], cache["v"], pos, batch_divisible=True
        )
        out = jnp.einsum("bshe,hed->bsd", o, params[f"{name}.wo"])
        return shard(out, "batch", "seq", "embed"), {"k": k_cache, "v": v_cache, "len": pos + 1}
    write_at = jnp.mod(pos, s_c) if circular else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), write_at, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), write_at, axis=1)
    k_cache = shard(k_cache, "batch", "kv_seq", "kv_heads", "head_dim")
    v_cache = shard(v_cache, "batch", "kv_seq", "kv_heads", "head_dim")
    cur = jnp.minimum(pos + 1, s_c) if circular else pos + 1
    o = attn_ops.decode_attention(q, k_cache, v_cache, cur, window=0 if circular else window)
    out = jnp.einsum("bshe,hed->bsd", o, params[f"{name}.wo"])
    new_cache = {"k": k_cache, "v": v_cache, "len": pos + 1}
    return shard(out, "batch", "seq", "embed"), new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kv, dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((batch, max_len, kv, dh), dtype),
        "v": jnp.zeros((batch, max_len, kv, dh), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def attention_cache_axes():
    return {
        "k": ("batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
        "len": (),
    }


# ---------------------------------------------------------------------------
# MLP (gated GLU or plain)
# ---------------------------------------------------------------------------


def init_mlp(b: ParamBuilder, name: str, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.gated_mlp:
        b.dense(f"{name}.wi_gate", (d, f), ("fsdp", "mlp"))
        b.dense(f"{name}.wi_up", (d, f), ("fsdp", "mlp"))
    else:
        b.dense(f"{name}.wi_up", (d, f), ("fsdp", "mlp"))
    b.dense(f"{name}.wo", (f, d), ("mlp", "fsdp"))


def _act(cfg: ModelConfig, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def apply_mlp(cfg: ModelConfig, params, name: str, x):
    up = jnp.einsum("bsd,df->bsf", x, params[f"{name}.wi_up"])
    if cfg.gated_mlp:
        gate = jnp.einsum("bsd,df->bsf", x, params[f"{name}.wi_gate"])
        h = _act(cfg, gate) * up
    else:
        h = _act(cfg, up)
    h = shard(h, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", h, params[f"{name}.wo"])
    return shard(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(b: ParamBuilder, cfg: ModelConfig):
    # vocab padded to a TPU-friendly multiple (MaxText-style): padded ids are
    # never label targets, so their logits only add (trainable-away) softmax mass
    v = cfg.padded_vocab
    b.dense("embed.tokens", (v, cfg.d_model), ("vocab", "embed"), scale=1.0)
    if cfg.learned_pos:
        b.dense("embed.positions", (cfg.max_position, cfg.d_model), (None, "embed"), scale=0.02)
    if not cfg.tie_embeddings:
        b.dense("unembed", (cfg.d_model, v), ("embed", "vocab"))


def embed_tokens(cfg: ModelConfig, params, tokens, position_offset=0):
    x = jnp.take(params["embed.tokens"], tokens, axis=0)
    if cfg.learned_pos:
        pos = jnp.arange(tokens.shape[1]) + position_offset
        x = x + jnp.take(params["embed.positions"], pos, axis=0)[None]
    return shard(x, "batch", "seq", "embed")


def unembed(cfg: ModelConfig, params, x):
    w = params["embed.tokens"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return shard(logits, "batch", "seq", "vocab")
