"""Expert-parallel MoE with sort-based capacity dispatch.

TPU-native adaptation (DESIGN.md §5): no dynamic shapes, no (tokens, E)
cumsum materialization.  Per batch row (rows are data-sharded, so dispatch
index arithmetic is row-local):

  1. top-k routing (normalized weights),
  2. position-in-expert via argsort over expert ids + per-expert offsets
     (scatter-add histogram — O(T*k) memory, never O(T*E)),
  3. scatter tokens into an (E, C, d) buffer sharded experts->model
     (XLA SPMD turns the data->model routing into collectives),
  4. grouped expert GEMMs batched over (row, expert),
  5. gather back + weighted combine; tokens past capacity fall through on
     the residual path (standard capacity dropping).

Aux outputs: GShard load-balance loss and the dropped-token fraction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamBuilder
from repro.parallel import shard


def init_moe(b: ParamBuilder, name: str, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    b.dense(f"{name}.router", (d, e), ("embed", None), scale=0.02)
    if cfg.gated_mlp:
        b.dense(f"{name}.wi_gate", (e, d, f), ("experts", "fsdp", "mlp"))
    b.dense(f"{name}.wi_up", (e, d, f), ("experts", "fsdp", "mlp"))
    b.dense(f"{name}.wo", (e, f, d), ("experts", "mlp", "fsdp"))


def moe_capacity(cfg: ModelConfig, tokens_per_row: int) -> int:
    ideal = tokens_per_row * cfg.top_k / cfg.n_experts
    return max(1, int(ideal * cfg.capacity_factor + 0.5))


def apply_moe(cfg: ModelConfig, params, name: str, x):
    """x: (B, S, d) -> (out, aux) with aux = {load_balance_loss, drop_frac}."""
    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = moe_capacity(cfg, s)
    tk = s * k

    logits = jnp.einsum("bsd,de->bse", x, params[f"{name}.router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (B, S, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # ---- position-in-expert via sort (row-local) --------------------------
    eid = top_e.reshape(bsz, tk)
    sort_idx = jnp.argsort(eid, axis=1, stable=True)  # (B, T*k)
    sorted_eid = jnp.take_along_axis(eid, sort_idx, axis=1)
    counts = jnp.zeros((bsz, e), jnp.int32).at[jnp.arange(bsz)[:, None], eid].add(1)
    offsets = jnp.cumsum(counts, axis=1) - counts  # exclusive
    pos_sorted = jnp.arange(tk)[None, :] - jnp.take_along_axis(offsets, sorted_eid, axis=1)
    keep = pos_sorted < c
    pos_sorted = jnp.minimum(pos_sorted, c - 1)

    # ---- dispatch ---------------------------------------------------------
    tok_sorted = sort_idx // k  # originating token per assignment
    brange = jnp.arange(bsz)[:, None]
    gathered = x[brange, tok_sorted] * keep[..., None].astype(x.dtype)  # (B, T*k, d)
    buf = jnp.zeros((bsz, e, c, d), x.dtype).at[brange, sorted_eid, pos_sorted].add(gathered)
    buf = shard(buf, "batch", "experts", None, None)

    # ---- expert FFN (batched grouped GEMM) --------------------------------
    up = jnp.einsum("becd,edf->becf", buf, params[f"{name}.wi_up"])
    if cfg.gated_mlp:
        gate = jnp.einsum("becd,edf->becf", buf, params[f"{name}.wi_gate"])
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = shard(h, "batch", "experts", None, "mlp")
    out_buf = jnp.einsum("becf,efd->becd", h, params[f"{name}.wo"])
    out_buf = shard(out_buf, "batch", "experts", None, None)

    # ---- combine ----------------------------------------------------------
    back = out_buf[brange, sorted_eid, pos_sorted] * keep[..., None].astype(x.dtype)  # (B,T*k,d)
    w_sorted = jnp.take_along_axis(top_w.reshape(bsz, tk), sort_idx, axis=1)
    back = back * w_sorted[..., None].astype(x.dtype)
    y = jnp.zeros((bsz, s, d), x.dtype).at[brange, tok_sorted].add(back)
    y = shard(y, "batch", "seq", "embed")

    # ---- aux --------------------------------------------------------------
    frac_tokens = counts.astype(jnp.float32) / tk  # (B, E)
    frac_probs = jnp.mean(probs, axis=1)  # (B, E)
    lb_loss = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y, {"load_balance_loss": lb_loss, "drop_frac": drop_frac}
