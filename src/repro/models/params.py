"""Parameter pytrees with parallel logical-axes pytrees.

``ParamBuilder`` accumulates ``{name: array}`` and ``{name: axes-tuple}``
side by side; init is split-key deterministic.  For scan-over-layers, layer
params are stacked along a leading "layers" axis via ``stack_layers``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class ParamBuilder:
    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self._key = key
        self.dtype = dtype
        self.params: dict = {}
        self.axes: dict = {}

    def _next(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, name: str, shape: tuple[int, ...], axes: tuple, scale: float | None = None):
        """Truncated-normal init with 1/sqrt(fan_in) default scale."""
        fan_in = shape[0] if len(shape) > 1 else shape[0]
        std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
        self.params[name] = (jax.random.truncated_normal(self._next(), -2.0, 2.0, shape, jnp.float32) * std).astype(
            self.dtype
        )
        self.axes[name] = axes
        return self

    def zeros(self, name: str, shape: tuple[int, ...], axes: tuple, dtype=None):
        self.params[name] = jnp.zeros(shape, dtype or self.dtype)
        self.axes[name] = axes
        return self

    def ones(self, name: str, shape: tuple[int, ...], axes: tuple, dtype=None):
        self.params[name] = jnp.ones(shape, dtype or self.dtype)
        self.axes[name] = axes
        return self

    def const(self, name: str, value, axes: tuple):
        self.params[name] = value
        self.axes[name] = axes
        return self

    def sub(self, name: str, builder: "ParamBuilder"):
        self.params[name] = builder.params
        self.axes[name] = builder.axes
        return self

    def build(self) -> tuple[dict, dict]:
        return self.params, self.axes


def stack_layers(n_layers: int, key: jax.Array, make_layer):
    """vmap ``make_layer(key) -> (params, axes)`` over ``n_layers`` keys.

    Returns stacked params (leading "layers" dim) and axes with a "layers"
    logical axis prefixed.
    """
    keys = jax.random.split(key, n_layers)
    _, axes = make_layer(keys[0])  # structure probe (cheap: small configs; reused below)
    stacked = jax.vmap(lambda k: make_layer(k)[0])(keys)
    axes = jax.tree.map(
        lambda a: ("layers", *a),
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(v, (str, type(None))) for v in x),
    )
    return stacked, axes


def is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(v, (str, type(None))) for v in x)
