"""Config system: one dataclass drives every architecture family."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_impl: str = "dense"  # dense (annotation dispatch) | ep (shard_map, §Perf)

    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    # --- hybrid (recurrentgemma): RG-LRU + local attention ---
    window: int = 0  # local-attention window
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    rnn_width: int = 0  # 0 -> d_model

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_positions: int = 0  # frames after the (stubbed) conv frontend

    # --- vlm ---
    vision_tokens: int = 0  # patch embeddings per image (stub frontend)

    # --- common ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu (GLU) | gelu (plain MLP)
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    learned_pos: bool = False  # whisper
    max_position: int = 1 << 20
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.family == "ssm" and self.dt_rank == 0:
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))
        if self.family == "hybrid" and self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 (TPU lanes / mesh-divisible)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (assignment: SSM/hybrid/linear only)"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        total += d  # final norm

        def attn_params(n_heads, n_kv, d_head):
            return d * n_heads * d_head + 2 * d * n_kv * d_head + n_heads * d_head * d

        def mlp_params(d_ff, gated):
            return d * d_ff * (3 if gated else 2)

        if self.family == "ssm":
            di, n, r = self.d_inner, self.ssm_state, self.dt_rank
            per = (
                d * 2 * di  # in_proj
                + di * self.ssm_conv  # conv
                + di * (r + 2 * n)  # x_proj
                + r * di + di  # dt_proj
                + di * n + di  # A_log, D
                + di * d  # out_proj
                + d  # norm
            )
            return total + self.n_layers * per
        if self.family == "hybrid":
            pattern = self.block_pattern or ("rec",)
            rec = (
                d * 2 * self.rnn_width  # x/gate proj
                + self.rnn_width * self.ssm_conv
                + 2 * self.rnn_width * self.rnn_width  # rg-lru input/recurrence gates (diag-blocks approx)
                + self.rnn_width  # Lambda
                + self.rnn_width * d
                + d
            )
            att = attn_params(self.n_heads, self.n_kv_heads, self.d_head) + d
            mlp = mlp_params(self.d_ff, self.gated_mlp) + d
            per_layer = []
            for i in range(self.n_layers):
                kind = pattern[i % len(pattern)]
                per_layer.append((rec if kind == "rec" else att) + mlp)
            return total + sum(per_layer)

        att = attn_params(self.n_heads, self.n_kv_heads, self.d_head) + d
        if self.family == "moe":
            ff = self.n_experts * mlp_params(self.d_ff, self.gated_mlp) + d * self.n_experts
            if self.dense_residual:
                ff += mlp_params(self.d_ff, self.gated_mlp)
        else:
            ff = mlp_params(self.d_ff, self.gated_mlp)
        per = att + ff + d
        layers = self.n_layers
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = att + mlp_params(self.d_ff, self.gated_mlp) + 2 * d
            dec = 2 * att + mlp_params(self.d_ff, self.gated_mlp) + 3 * d
            return total + self.encoder_layers * enc + self.n_layers * dec + self.encoder_positions * d
        return total + layers * per

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        expert = d * self.d_ff * (3 if self.gated_mlp else 2)
        inactive = (self.n_experts - self.top_k) * expert
        return self.param_count() - self.n_layers * inactive
