"""Fault-tolerance substrate: sharded, atomic, async, (optionally) quantized
checkpointing with elastic restore."""

from repro.checkpoint.manager import CheckpointManager, CheckpointMeta

__all__ = ["CheckpointManager", "CheckpointMeta"]
