"""Fault-tolerance substrate: sharded, atomic, async, (optionally) quantized
checkpointing with elastic restore and typed corruption detection."""

from repro.checkpoint.manager import (
    CheckpointCorruptionError,
    CheckpointManager,
    CheckpointMeta,
)

__all__ = ["CheckpointCorruptionError", "CheckpointManager", "CheckpointMeta"]
