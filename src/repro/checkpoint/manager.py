"""Checkpoint manager: atomic, async, quantized, elastic.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json        # written LAST -> commit point
        leaf_00000.npy ...   # one file per pytree leaf (or .npz for int8)

Properties:

  * **Atomic**: writes go to ``step_X.tmp/``; the manifest is written last
    and the directory renamed — a checkpoint without a manifest is garbage
    and is ignored/cleaned.  A kill mid-checkpoint (the paper's out-of-bid
    case) can never corrupt the latest good checkpoint.
  * **Async**: ``save(..., block=False)`` snapshots to host memory
    synchronously (fast) and writes files on a background thread, so the
    training loop's effective t_c is the device->host copy, not the I/O.
  * **Quantized** (codec="int8"): kernels/ckpt_codec blocks — ~4x smaller
    files, directly shrinking the paper's t_c term.  Default codec="raw" is
    bit-exact.
  * **Elastic**: files store *global* arrays + the logical-axes tree; restore
    re-shards onto any mesh via device_put with the target NamedShardings.
  * **Integrity**: sha256 per leaf file, verified on restore.  Every way a
    checkpoint can be unreadable (missing/torn leaf, hash mismatch, mangled
    manifest) raises the typed :class:`CheckpointCorruptionError`, so callers
    can distinguish "this checkpoint is damaged — fall back to an older one"
    (see :meth:`SpotTrainer's <repro.train.spot_trainer.SpotTrainer>` degraded
    recovery) from programming errors.  :meth:`CheckpointManager.quarantine`
    renames a damaged step directory to ``*.corrupt`` — out of
    :meth:`steps`'s view, but preserved on disk as evidence.

Fault-injection sites (:mod:`repro.faults`): ``ckpt.save`` fires per write
(``raise`` = I/O failure, ``torn`` = a leaf file silently truncated after
hashing — detected only at restore) and ``ckpt.restore`` fires per restore
attempt (``raise`` = unreadable checkpoint), both keyed by step.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro import faults
from repro.kernels.ckpt_codec import ref as codec


@dataclasses.dataclass
class CheckpointMeta:
    step: int
    codec: str
    n_leaves: int
    wall_time_s: float
    bytes_written: int
    extra: dict


class CheckpointCorruptionError(IOError):
    """A checkpoint on disk cannot be restored (torn file, bad hash, mangled
    manifest).  Carries the step and path so recovery code can quarantine
    exactly the damaged snapshot and fall back to an older one."""

    def __init__(self, step: int | None, path: str, reason: str):
        self.step = step
        self.path = path
        self.reason = reason
        super().__init__(f"corrupt checkpoint step={step} ({path}): {reason}")


def _tree_paths(tree) -> list[str]:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(p) for p, _ in paths]


class CheckpointManager:
    def __init__(
        self,
        root: str,
        *,
        keep: int = 3,
        codec_name: str = "raw",  # raw | int8
        async_io: bool = False,
    ):
        self.root = root
        self.keep = keep
        self.codec_name = codec_name
        self.async_io = async_io
        self._thread: threading.Thread | None = None
        self._last_error: Exception | None = None
        os.makedirs(root, exist_ok=True)
        self._clean_tmp()

    # ------------------------------------------------------------------
    def _clean_tmp(self):
        for d in os.listdir(self.root):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith((".tmp", ".corrupt")):
                if os.path.exists(os.path.join(self.root, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def quarantine(self, step: int) -> str:
        """Move a damaged checkpoint out of :meth:`steps`'s view (renamed to
        ``step_X.corrupt``, kept on disk as evidence); returns the new path."""
        src = os.path.join(self.root, f"step_{step:09d}")
        dst = src + ".corrupt"
        if os.path.exists(dst):  # re-quarantine after a re-save of the step
            shutil.rmtree(dst, ignore_errors=True)
        os.replace(src, dst)
        return dst

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None, *, block: bool = True) -> CheckpointMeta:
        """Snapshot ``tree`` (pytree of jax/np arrays) at ``step``."""
        self.wait()  # one outstanding async save at a time (double-buffer)
        t0 = time.monotonic()
        # synchronous part: device -> host (this is the training pause = t_c)
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]
        snap_time = time.monotonic() - t0
        meta_holder: dict = {}

        def write():
            try:
                meta_holder["meta"] = self._write(step, host_leaves, treedef, extra or {}, snap_time)
            except Exception as e:  # surfaced on next wait()
                self._last_error = e

        if block or not self.async_io:
            write()
            if self._last_error:
                err, self._last_error = self._last_error, None
                raise err
            return meta_holder["meta"]
        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        return CheckpointMeta(step, self.codec_name, len(host_leaves), snap_time, 0, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    # ------------------------------------------------------------------
    def _write(self, step, host_leaves, treedef, extra, snap_time) -> CheckpointMeta:
        name = f"step_{step:09d}"
        tmp = os.path.join(self.root, name + ".tmp")
        final = os.path.join(self.root, name)
        action = faults.current().fire("ckpt.save", key=step)
        if action is not None and action.kind == "raise":
            raise faults.InjectedFault(action)  # async saves surface this on wait()
        os.makedirs(tmp, exist_ok=True)
        files = []
        total = 0
        for i, leaf in enumerate(host_leaves):
            fname = f"leaf_{i:05d}"
            path = os.path.join(tmp, fname)
            is_float = leaf.dtype in (np.float32, np.float16) or str(leaf.dtype) == "bfloat16"
            if self.codec_name == "int8" and is_float and leaf.size >= 1024:
                q, scales, shape = codec.quantize(leaf)
                np.savez(
                    path,
                    q=np.asarray(q),
                    scales=np.asarray(scales),
                    shape=np.asarray(shape, dtype=np.int64),
                )
                path += ".npz"
            else:
                # npy cannot store bfloat16: write the uint16 view; the
                # manifest dtype tag drives the view back on restore
                np.save(path, leaf.view(np.uint16) if str(leaf.dtype) == "bfloat16" else leaf)
                path += ".npy"
            h = hashlib.sha256(open(path, "rb").read()).hexdigest()
            total += os.path.getsize(path)
            files.append({"file": os.path.basename(path), "sha256": h, "dtype": str(leaf.dtype)})
        if action is not None and action.kind == "torn" and files:
            # silent torn write: the commit completes but one leaf is
            # truncated after hashing — only restore's integrity check sees it
            torn = os.path.join(tmp, files[0]["file"])
            data = open(torn, "rb").read()
            open(torn, "wb").write(data[: len(data) // 2])
        manifest = {
            "step": step,
            "codec": self.codec_name,
            "treedef": str(treedef),
            "files": files,
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(tmp)
        else:
            os.replace(tmp, final)
        self._gc()
        return CheckpointMeta(step, self.codec_name, len(host_leaves), snap_time, total, extra)

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, template, step: int | None = None, *, shardings=None) -> tuple:
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for elastic placement onto a (different) mesh.

        Returns (tree, extra).
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:09d}")
        action = faults.current().fire("ckpt.restore", key=step)
        if action is not None:
            raise CheckpointCorruptionError(step, d, f"injected: {action.describe()}")
        try:
            manifest = json.load(open(os.path.join(d, "manifest.json")))
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CheckpointCorruptionError(step, d, f"unreadable manifest: {e}") from e
        leaves_t, treedef = jax.tree.flatten(template)
        if len(manifest["files"]) != len(leaves_t):
            raise ValueError(
                f"checkpoint has {len(manifest['files'])} leaves, template has {len(leaves_t)}"
            )
        out = []
        for i, (entry, tmpl) in enumerate(zip(manifest["files"], leaves_t)):
            path = os.path.join(d, entry["file"])
            try:
                data = open(path, "rb").read()
            except OSError as e:
                raise CheckpointCorruptionError(step, path, f"missing leaf file: {e}") from e
            if hashlib.sha256(data).hexdigest() != entry["sha256"]:
                raise CheckpointCorruptionError(step, path, "leaf sha256 mismatch (torn write?)")
            try:
                if path.endswith(".npz"):
                    z = np.load(path)
                    import jax.numpy as jnp

                    arr = np.asarray(
                        codec.dequantize(
                            jnp.asarray(z["q"]), jnp.asarray(z["scales"]), tuple(z["shape"])
                        )
                    ).astype(_np_dtype(entry["dtype"]))
                else:
                    arr = np.load(path)
                    if entry["dtype"] == "bfloat16":
                        import ml_dtypes  # vendored with jax

                        arr = arr.view(ml_dtypes.bfloat16)
            except (ValueError, KeyError, EOFError, OSError) as e:
                raise CheckpointCorruptionError(step, path, f"undecodable leaf: {e}") from e
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != template {tmpl.shape}")
            out.append(arr)
        tree = jax.tree.unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, manifest["extra"]


def _np_dtype(name: str):
    if name == "bfloat16":
        import ml_dtypes

        return ml_dtypes.bfloat16
    return np.dtype(name)
