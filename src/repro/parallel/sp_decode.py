"""Sequence-parallel (SP) decode attention: distributed flash-decoding.

For decode shapes whose KV cache is sequence-sharded over "model"
(rules["kv_seq"] == "model"), the annotation-only version lets the SPMD
partitioner all-gather the cache every layer (measured: +96 all-gathers,
23x wire bytes on internlm2 decode_32k — §Perf iter 1).  This shard_map
version computes the online-softmax partials (m, l, o) on each rank's local
KV slice and combines with pmax/psum — wire cost per layer drops from
O(B*S*KV*D) to O(B*H*D).

Also handles the cache append: only the rank owning slot ``pos`` writes.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import active_abstract_mesh, compat_shard_map

NEG_INF = -1e30


def sp_available(s_c: int) -> bool:
    mesh = active_abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return False
    tp = dict(zip(mesh.axis_names, mesh.axis_sizes))["model"]
    return s_c % tp == 0


def sp_decode_attention_update(q, k_new, v_new, k_cache, v_cache, pos, batch_divisible: bool):
    """q: (B,1,H,D); k_new/v_new: (B,1,KV,D); caches (B,S,KV,D) seq-sharded.

    Returns (out (B,1,H,D), new_k, new_v).  ``pos``: scalar int32 append slot.
    """
    mesh = active_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    tp = sizes["model"]
    b, _, h, d = q.shape
    _, s_c, n_kv, _ = k_cache.shape
    s_loc = s_c // tp
    g = h // n_kv

    batch_axes = [a for a in ("pod", "data") if a in sizes]
    prod = 1
    kept = []
    for a in batch_axes:
        if batch_divisible and b % (prod * sizes[a]) == 0:
            kept.append(a)
            prod *= sizes[a]
    bspec = tuple(kept) if len(kept) > 1 else (kept[0] if kept else None)

    def shard_fn(q_blk, kn, vn, kc, vc, pos_s):
        rank = jax.lax.axis_index("model")
        # --- append: only the owning rank writes slot pos ------------------
        local = pos_s - rank * s_loc
        owner = (local >= 0) & (local < s_loc)
        idx = jnp.clip(local, 0, s_loc - 1)
        cur_k = jax.lax.dynamic_slice_in_dim(kc, idx, 1, axis=1)
        cur_v = jax.lax.dynamic_slice_in_dim(vc, idx, 1, axis=1)
        upd_k = jnp.where(owner, kn.astype(kc.dtype), cur_k)
        upd_v = jnp.where(owner, vn.astype(vc.dtype), cur_v)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, upd_k, idx, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, upd_v, idx, axis=1)

        # --- local partial attention ---------------------------------------
        qg = q_blk.reshape(q_blk.shape[0], n_kv, g, d).astype(jnp.float32)
        s = jnp.einsum("bkgd,bckd->bkgc", qg, kc.astype(jnp.float32)) * (1.0 / math.sqrt(d))
        pos_abs = rank * s_loc + jnp.arange(s_loc)
        mask = pos_abs[None, :] < (pos_s + 1)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)  # (b,k,g)
        p = jnp.exp(s - m_loc[..., None])
        p = jnp.where(mask[:, None, None, :], p, 0.0)
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bkgc,bckd->bkgd", p, vc.astype(jnp.float32))

        # --- combine across ranks (flash-decoding merge) -------------------
        m_glob = jax.lax.pmax(m_loc, "model")
        alpha = jnp.exp(m_loc - m_glob)
        l_glob = jax.lax.psum(l_loc * alpha, "model")
        o_glob = jax.lax.psum(o_loc * alpha[..., None], "model")
        out = (o_glob / jnp.maximum(l_glob, 1e-37)[..., None]).reshape(q_blk.shape[0], 1, h, d)
        return out.astype(q_blk.dtype), kc, vc

    out, new_k, new_v = compat_shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),  # q replicated over model
            P(bspec, None, None, None),
            P(bspec, None, None, None),
            P(bspec, "model", None, None),  # seq-sharded caches
            P(bspec, "model", None, None),
            P(),
        ),
        out_specs=(
            P(bspec, None, None, None),
            P(bspec, "model", None, None),
            P(bspec, "model", None, None),
        ),
    )(q, k_new, v_new, k_cache, v_cache, pos)
    return out, new_k, new_v
