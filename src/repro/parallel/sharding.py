"""Logical-axis sharding: flax-style rules mapping logical names to mesh axes.

Model code annotates tensors with *logical* axes (``("batch","seq","embed")``)
and never mentions the mesh.  A rule set maps logical -> mesh axes; inside an
active mesh, :func:`shard` becomes ``with_sharding_constraint`` and
:func:`logical_sharding` builds ``NamedSharding`` for jit in/out shardings.
Outside a mesh everything is a no-op, so single-device smoke tests run the
same code path.

Parallelism styles expressed purely through rules (DESIGN.md §5):

  * DP/FSDP  — "batch" and the designated fsdp param axis -> ("pod","data")
  * TP       — "heads"/"mlp"/"vocab"/"kv_heads" -> "model"
  * EP       — "experts" -> "model"
  * SP       — "kv_seq" -> "model" for long-context decode
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None)
Rules = dict[str, object]

# Baseline 2D (+pod) rules: FSDP over (pod, data) on the "fsdp" logical axis,
# tensor parallelism over "model".
DEFAULT_RULES: Rules = {
    # data axes
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,  # set to "model" for SP long-context decode
    # param/activation axes
    "embed": None,
    "fsdp": ("pod", "data"),  # ZeRO-3 axis: largest param dim not on "model"
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_group": ("pod", "data"),
    "vocab": "model",
    "layers": None,
    "conv": None,
    "state": None,
    "rnn": "model",
}

_local = threading.local()


def current_rules() -> Rules:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(rules: Rules):
    prev = getattr(_local, "rules", None)
    _local.rules = rules
    try:
        yield
    finally:
        if prev is None:
            del _local.rules
        else:
            _local.rules = prev


def active_abstract_mesh():
    """The mesh set by the innermost ``with mesh:`` context (or an empty one).

    ``jax.sharding.get_abstract_mesh`` first shipped in jax 0.5; on older
    installs fall back to the physical mesh that ``with mesh:`` pushes onto
    the thread-resources env — same ``.empty``/``.axis_names``/``.axis_sizes``
    surface, so every caller is version-agnostic.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh


def make_compat_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` with explicit-Auto axis types where the installed jax
    supports them (``jax.sharding.AxisType`` arrived in 0.5; older versions
    only have Auto semantics, so plain ``make_mesh`` is equivalent there)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(shape))
    return jax.make_mesh(shape, axes)


def compat_shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` (0.5+) or ``jax.experimental.shard_map.shard_map``
    (0.4.x) — identical semantics, the symbol just moved."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm  # noqa: N813
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def use_compat_mesh(mesh: Mesh):
    """Context manager making ``mesh`` ambient: ``jax.sharding.set_mesh``
    where available (jax 0.5+), else the classic ``with mesh:`` form — both
    are what :func:`active_abstract_mesh` reads back."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def _active_mesh() -> Mesh | None:
    mesh = active_abstract_mesh()  # set by `with mesh:` contexts
    if mesh is None or mesh.empty:
        return None
    return mesh


def _axis_len(mesh, name: str) -> int:
    # works for both Mesh and AbstractMesh
    return dict(zip(mesh.axis_names, mesh.axis_sizes))[name]


def _spec_for(
    logical_axes: tuple[str | None, ...],
    rules: Rules,
    mesh,
    shape: tuple[int, ...] | None = None,
) -> P:
    """Logical axes -> PartitionSpec.  Shape-aware: a mapping whose mesh-axis
    product does not divide the dimension is dropped (e.g. GQA kv_heads=2 on
    a 16-wide model axis stays replicated; FSDP on dim 0 still shards the
    tensor).  Mesh axes are never used twice in one spec."""
    mesh_axes = set(mesh.axis_names)
    out = []
    used: set[str] = set()
    for i, ax in enumerate(logical_axes):
        if ax is None:
            out.append(None)
            continue
        target = rules.get(ax)
        if target is None:
            out.append(None)
            continue
        if isinstance(target, str):
            target = (target,)
        picked = [t for t in target if t in mesh_axes and t not in used]
        if shape is not None and picked:
            dim = shape[i]
            # greedily keep the prefix of mesh axes whose product divides dim
            kept = []
            prod = 1
            for t in picked:
                n = _axis_len(mesh, t)
                if dim % (prod * n) == 0:
                    kept.append(t)
                    prod *= n
            picked = kept
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    return P(*out)


def shard(x, *logical_axes: str | None):
    """Annotate ``x`` with logical axes; no-op outside a mesh context."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    spec = _spec_for(tuple(logical_axes), current_rules(), mesh, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def logical_sharding(
    mesh: Mesh,
    logical_axes: tuple[str | None, ...],
    rules: Rules | None = None,
    shape: tuple[int, ...] | None = None,
) -> NamedSharding:
    return NamedSharding(mesh, _spec_for(tuple(logical_axes), rules or current_rules(), mesh, shape))


def shard_params(mesh: Mesh, axes_tree, rules: Rules | None = None, abstract_tree=None):
    """Pytree of logical-axis tuples -> pytree of NamedShardings.

    ``abstract_tree``: matching pytree of arrays/ShapeDtypeStructs enabling
    shape-aware divisibility fallbacks."""
    rules = rules or current_rules()
    is_leaf = lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)
    if abstract_tree is None:
        return jax.tree.map(lambda axes: logical_sharding(mesh, axes, rules), axes_tree, is_leaf=is_leaf)
    flat_axes, tdef = jax.tree.flatten(axes_tree, is_leaf=is_leaf)
    flat_abs = tdef.flatten_up_to(abstract_tree)
    return tdef.unflatten(
        [logical_sharding(mesh, a, rules, tuple(x.shape)) for a, x in zip(flat_axes, flat_abs)]
    )
