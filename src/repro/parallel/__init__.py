"""Distribution substrate: mesh conventions, logical-axis sharding rules,
collective helpers and optional pipeline parallelism."""

from repro.parallel.sharding import (
    DEFAULT_RULES,
    axis_rules,
    current_rules,
    logical_sharding,
    shard,
    shard_params,
)

__all__ = [
    "DEFAULT_RULES",
    "axis_rules",
    "current_rules",
    "logical_sharding",
    "shard",
    "shard_params",
]
