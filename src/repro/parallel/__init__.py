"""Distribution substrate: mesh conventions, logical-axis sharding rules,
collective helpers and optional pipeline parallelism."""

from repro.parallel.sharding import (
    DEFAULT_RULES,
    active_abstract_mesh,
    axis_rules,
    compat_shard_map,
    current_rules,
    logical_sharding,
    make_compat_mesh,
    shard,
    shard_params,
    use_compat_mesh,
)

__all__ = [
    "DEFAULT_RULES",
    "active_abstract_mesh",
    "axis_rules",
    "compat_shard_map",
    "current_rules",
    "logical_sharding",
    "make_compat_mesh",
    "shard",
    "shard_params",
    "use_compat_mesh",
]
